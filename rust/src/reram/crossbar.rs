//! The crossbar array model.
//!
//! One [`Crossbar`] is a 128x128 array of 2-bit ReRAM cells storing one
//! bit-slice of one sign (positive or negative weights map to separate
//! arrays — state-of-the-art accelerators keep them on differential column
//! pairs [10]). Wordlines are driven bit-serially by 1-bit DACs; the
//! bitline current is the dot product of the input bit vector with the
//! column's conductances, in units of one minimum-conductance cell (the
//! ADC's LSB).

/// ISAAC-style array geometry.
pub const XBAR_ROWS: usize = 128;
pub const XBAR_COLS: usize = 128;

/// Max cell conductance value for 2-bit cells.
pub const CELL_MAX: u8 = 3;

/// A single crossbar array holding 2-bit cells.
#[derive(Debug, Clone)]
pub struct Crossbar {
    /// row-major `rows x cols`, values 0..=3
    cells: Vec<u8>,
    rows: usize,
    cols: usize,
}

impl Crossbar {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        assert!(rows <= XBAR_ROWS && cols <= XBAR_COLS, "{rows}x{cols}");
        Crossbar {
            cells: vec![0; rows * cols],
            rows,
            cols,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn set(&mut self, r: usize, c: usize, v: u8) {
        assert!(v <= CELL_MAX, "cell value {v}");
        self.cells[r * self.cols + c] = v;
    }

    pub fn get(&self, r: usize, c: usize) -> u8 {
        self.cells[r * self.cols + c]
    }

    /// Number of programmed (non-zero) cells — the mapped-sparsity census.
    pub fn nonzero_cells(&self) -> usize {
        self.cells.iter().filter(|&&v| v != 0).count()
    }

    /// Per-column sum of conductances: the worst-case bitline current
    /// (every wordline driving a '1'), in LSB units.
    pub fn column_conductance_sums(&self) -> Vec<u32> {
        let mut sums = vec![0u32; self.cols];
        for r in 0..self.rows {
            let row = &self.cells[r * self.cols..(r + 1) * self.cols];
            for (c, &v) in row.iter().enumerate() {
                sums[c] += v as u32;
            }
        }
        sums
    }

    /// Bitline currents for one input bit-plane (`bits[r]` in {0,1}).
    pub fn bitline_currents(&self, bits: &[u8], out: &mut [u32]) {
        debug_assert_eq!(bits.len(), self.rows);
        debug_assert_eq!(out.len(), self.cols);
        out.fill(0);
        for r in 0..self.rows {
            if bits[r] == 0 {
                continue;
            }
            let row = &self.cells[r * self.cols..(r + 1) * self.cols];
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, ensure};

    #[test]
    fn geometry_limits_enforced() {
        let xb = Crossbar::zeros(128, 128);
        assert_eq!((xb.rows(), xb.cols()), (128, 128));
    }

    #[test]
    #[should_panic]
    fn oversized_array_panics() {
        let _ = Crossbar::zeros(129, 10);
    }

    #[test]
    #[should_panic]
    fn cell_value_range_enforced() {
        let mut xb = Crossbar::zeros(2, 2);
        xb.set(0, 0, 4);
    }

    #[test]
    fn column_sums_and_currents_agree_for_all_ones_input() {
        check(25, |rng| {
            let rows = 1 + rng.below(128);
            let cols = 1 + rng.below(128);
            let mut xb = Crossbar::zeros(rows, cols);
            for r in 0..rows {
                for c in 0..cols {
                    xb.set(r, c, rng.below(4) as u8);
                }
            }
            let bits = vec![1u8; rows];
            let mut cur = vec![0u32; cols];
            xb.bitline_currents(&bits, &mut cur);
            ensure(
                cur == xb.column_conductance_sums(),
                "all-ones currents == column sums",
            )?;
            Ok(())
        });
    }

    #[test]
    fn currents_respect_input_bits() {
        let mut xb = Crossbar::zeros(3, 2);
        xb.set(0, 0, 3);
        xb.set(1, 0, 2);
        xb.set(2, 1, 1);
        let mut cur = vec![0u32; 2];
        xb.bitline_currents(&[1, 0, 1], &mut cur);
        assert_eq!(cur, vec![3, 1]);
    }

    #[test]
    fn nonzero_cell_census() {
        let mut xb = Crossbar::zeros(4, 4);
        assert_eq!(xb.nonzero_cells(), 0);
        xb.set(1, 2, 2);
        xb.set(3, 3, 1);
        assert_eq!(xb.nonzero_cells(), 2);
    }
}

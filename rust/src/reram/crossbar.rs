//! The crossbar array model.
//!
//! One [`Crossbar`] is a 128x128 array of 2-bit ReRAM cells storing one
//! bit-slice of one sign (positive or negative weights map to separate
//! arrays — state-of-the-art accelerators keep them on differential column
//! pairs [10]). Wordlines are driven bit-serially by 1-bit DACs; the
//! bitline current is the dot product of the input bit vector with the
//! column's conductances, in units of one minimum-conductance cell (the
//! ADC's LSB).
//!
//! # Storage formats
//!
//! Bit-slice L1 training drives each 2-bit slice toward ~90%+ zeros, so a
//! tile's cells live behind a polymorphic `CellArray`:
//!
//! * **Dense** — the row-major `Vec<u8>` layout; right for tiles where
//!   most cells are programmed (sequential scan, one byte per cell).
//! * **Compressed** — per-row packed `(col, val)` pairs (CSR-style
//!   `row_ptr` offsets) plus a nonzero-wordline index, so
//!   [`Crossbar::bitline_currents`] touches only programmed cells on
//!   active wordlines, and a nonzero-**column** index
//!   ([`Crossbar::active_cols`]) so the per-tile ADC/recombination loop
//!   ([`Crossbar::bitline_currents_active`]) skips structurally-zero
//!   output columns outright — the remaining O(cols) term at extreme
//!   sparsity.
//! * **BitPlanes** — column-major packed cell-bit masks: per column, one
//!   `[u64; 2]` mask per cell bit over the <= 128 rows (see the packing
//!   convention in the `reram` module docs). With the activation
//!   bit-plane packed into the same `[u64; 2]` wave form, a column's
//!   current is `popcount(plane0 & wave) + (popcount(plane1 & wave) << 1)`
//!   — ~4 word ops instead of up to 128 byte multiply-adds, the win in
//!   the *moderate* density band where `Compressed` has no skip leverage
//!   left but the dense byte scan is pure waste. Carries the same
//!   nonzero-column index as `Compressed`, so the ADC / energy /
//!   resolution / timing accounting is identical.
//!
//! The representation is chosen per tile from its measured density — a
//! three-band policy with one definition, [`chosen_format`]: `Compressed`
//! at or below [`COMPRESS_MAX_DENSITY`], `BitPlanes` in the mid band up
//! to [`BITPLANE_MAX_DENSITY`], `Dense` above it. The mapper builds
//! compressed and bit-plane tiles directly without a dense intermediate.
//! The programmed-cell census is cached in the tile (maintained by
//! [`Crossbar::set`], established at build time), so
//! [`Crossbar::nonzero_cells`] is O(1) — the energy roll-up, the planner's
//! scoring loop and the reports stop recounting `rows * cols` cells.

/// ISAAC-style array geometry.
pub const XBAR_ROWS: usize = 128;
pub const XBAR_COLS: usize = 128;

/// Max cell conductance value for 2-bit cells.
pub const CELL_MAX: u8 = 3;

/// Densest tile (programmed cells / total cells) still stored compressed.
///
/// Measured crossover: one compressed entry costs exactly 3 bytes (the
/// `(col, val)` pair lives as parallel `u16`/`u8` arrays — a tuple would
/// pad to 4) and one scattered add, versus the dense row's one byte and
/// one sequential add per cell, so memory parity sits at 1/3 density and
/// the sparse scan wins comfortably below it. A quarter leaves margin for
/// the scatter penalty and the `row_ptr` overhead; Bl1-level slices
/// (>= 85% zeros, i.e. <= 15% density) sit far below it.
pub const COMPRESS_MAX_DENSITY: f64 = 0.25;

/// Densest tile stored as packed bit-planes; above this the tile stays in
/// the row-major byte layout.
///
/// The popcount scan's cost is density-independent (~4 word ops per
/// column per plane), so the band's *lower* edge is simply where
/// `Compressed` stops winning ([`COMPRESS_MAX_DENSITY`]). The upper edge
/// keeps the byte layout as the canonical near-full representation:
/// above ~60% density nearly every column is active anyway, `set`-heavy
/// programming is cheapest on flat bytes, and the dense scan is the
/// paper's naive digital baseline — the benches need it to stay honestly
/// reachable. Dense-random slices (~37% per sign grid) land mid-band and
/// get the popcount path; bit-slice-L1-trained slices fall through to
/// `Compressed`.
pub const BITPLANE_MAX_DENSITY: f64 = 0.60;

/// How a tile's cells are laid out in memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorageFormat {
    /// row-major `Vec<u8>`, one byte per cell
    Dense,
    /// per-row packed `(col, val)` pairs + nonzero-wordline index
    Compressed,
    /// column-major `[u64; 2]` cell-bit masks + nonzero-column index
    BitPlanes,
}

/// The format [`Crossbar::pack`] and the mapper choose for a tile with
/// `nonzero` of `rows * cols` cells programmed — the one density-band
/// policy every call site shares: `Compressed` at or below
/// [`COMPRESS_MAX_DENSITY`], `BitPlanes` up to [`BITPLANE_MAX_DENSITY`],
/// `Dense` above.
pub fn chosen_format(nonzero: usize, rows: usize, cols: usize) -> StorageFormat {
    let cells = (rows * cols).max(1);
    let density = nonzero as f64 / cells as f64;
    if density <= COMPRESS_MAX_DENSITY {
        StorageFormat::Compressed
    } else if density <= BITPLANE_MAX_DENSITY {
        StorageFormat::BitPlanes
    } else {
        StorageFormat::Dense
    }
}

/// Pack a byte bit-plane (`bits[r]` non-zero = wordline `r` driven) into
/// the `[u64; 2]` wave-mask form of the BitPlanes convention: wordline
/// `r` is bit `r % 64` of word `r / 64`.
pub fn pack_wave(bits: &[u8]) -> [u64; 2] {
    assert!(bits.len() <= XBAR_ROWS, "wave of {} wordlines", bits.len());
    let mut wave = [0u64; 2];
    for (r, &b) in bits.iter().enumerate() {
        wave[r >> 6] |= ((b != 0) as u64) << (r & 63);
    }
    wave
}

/// Pack activation bit `bit` of each code straight into the `[u64; 2]`
/// wave-mask form — [`pack_wave`] without the intermediate byte plane.
/// Identical mask for identical codes: wordline `r` is driven exactly when
/// `codes[r]` has bit `bit` set.
pub fn pack_code_wave(codes: &[u8], bit: u32) -> [u64; 2] {
    assert!(codes.len() <= XBAR_ROWS, "wave of {} wordlines", codes.len());
    let mut wave = [0u64; 2];
    for (r, &c) in codes.iter().enumerate() {
        wave[r >> 6] |= (((c >> bit) & 1) as u64) << (r & 63);
    }
    wave
}

/// Physical cell storage of one tile — see the module docs for when each
/// representation wins.
#[derive(Debug, Clone)]
enum CellArray {
    /// row-major `rows x cols`, values 0..=3
    Dense(Vec<u8>),
    Compressed {
        /// entry range of row `r` is `row_ptr[r]..row_ptr[r + 1]`
        row_ptr: Vec<u32>,
        /// `(column, value)` pairs as parallel arrays (3 bytes per entry,
        /// no tuple padding), column-ascending within each row
        entry_cols: Vec<u16>,
        entry_vals: Vec<u8>,
        /// rows holding >= 1 programmed cell, ascending — the
        /// nonzero-wordline index the sparse current scan walks
        active_rows: Vec<u16>,
        /// columns holding >= 1 programmed cell, ascending — the
        /// nonzero-column index the per-tile ADC loop walks; a column
        /// outside it can never carry current, so its conversion is
        /// skipped outright
        active_cols: Vec<u16>,
    },
    BitPlanes {
        /// per column, the mask of rows whose cell has bit 0 set —
        /// row `r` is bit `r % 64` of word `r / 64`
        plane0: Vec<[u64; 2]>,
        /// per column, the mask of rows whose cell has bit 1 set
        plane1: Vec<[u64; 2]>,
        /// nonzero-column index, ascending — same ADC-skip semantics as
        /// the compressed layout's
        active_cols: Vec<u16>,
    },
}

/// Assemble the packed bit-plane arrays from `(row, col, val)` triples
/// (positions unique, `row < rows`, `col < cols`, `val` in `1..=3`) — the
/// one bit-plane builder [`Crossbar::from_cells`] and
/// [`Crossbar::convert`] share. Triples may arrive in any order: each
/// lands as independent OR-ed bits.
fn build_bitplanes(
    rows: usize,
    cols: usize,
    cells: impl Iterator<Item = (usize, u16, u8)>,
) -> CellArray {
    debug_assert!(rows <= XBAR_ROWS);
    let mut plane0 = vec![[0u64; 2]; cols];
    let mut plane1 = vec![[0u64; 2]; cols];
    let mut col_seen = vec![false; cols];
    for (r, c, v) in cells {
        let c = c as usize;
        let (w, b) = (r >> 6, r & 63);
        plane0[c][w] |= ((v & 1) as u64) << b;
        plane1[c][w] |= (((v >> 1) & 1) as u64) << b;
        col_seen[c] = true;
    }
    let active_cols = (0..cols)
        .filter(|&c| col_seen[c])
        .map(|c| c as u16)
        .collect();
    CellArray::BitPlanes {
        plane0,
        plane1,
        active_cols,
    }
}

/// Assemble the CSR arrays from row-major `(row, col, val)` triples (row
/// ascending, column ascending within a row, `row < rows`, `col < cols`,
/// `val != 0`) — the one compressed-layout builder
/// [`Crossbar::from_cells`] and [`Crossbar::convert`] share, so the
/// representation's invariants live in a single place.
fn build_compressed(
    rows: usize,
    cols: usize,
    cells: impl Iterator<Item = (usize, u16, u8)>,
) -> CellArray {
    let hint = cells.size_hint().0;
    let mut row_ptr = vec![0u32; rows + 1];
    let mut entry_cols = Vec::with_capacity(hint);
    let mut entry_vals = Vec::with_capacity(hint);
    let mut col_seen = vec![false; cols];
    for (r, c, v) in cells {
        row_ptr[r + 1] += 1;
        col_seen[c as usize] = true;
        entry_cols.push(c);
        entry_vals.push(v);
    }
    for r in 0..rows {
        row_ptr[r + 1] += row_ptr[r];
    }
    let active_rows = (0..rows)
        .filter(|&r| row_ptr[r + 1] > row_ptr[r])
        .map(|r| r as u16)
        .collect();
    let active_cols = (0..cols)
        .filter(|&c| col_seen[c])
        .map(|c| c as u16)
        .collect();
    CellArray::Compressed {
        row_ptr,
        entry_cols,
        entry_vals,
        active_rows,
        active_cols,
    }
}

/// One structural fault [`Crossbar::verify_cells`] found in a tile's
/// storage — the raw material `reram::audit` turns into typed
/// diagnostics (each variant maps onto one stable audit code).
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) enum TileFault {
    /// a stored cell value outside `1..=CELL_MAX`
    ValueOutOfRange { row: usize, col: usize, value: u8 },
    /// cached census != a recount over the actual store
    CensusMismatch { cached: usize, actual: usize },
    /// compressed-layout inconsistency: `row_ptr` / entry / active-index
    /// drift
    IndexInconsistent(String),
    /// bit-plane inconsistency: plane shape, stray padding bits, or
    /// column-index drift
    PlaneMaskInconsistent(String),
}

/// A single crossbar array holding 2-bit cells.
#[derive(Debug, Clone)]
pub struct Crossbar {
    store: CellArray,
    rows: usize,
    cols: usize,
    /// programmed-cell census, maintained incrementally — never recounted
    nonzero: usize,
}

impl Crossbar {
    /// An all-zero tile in dense layout (the mutable starting point;
    /// [`Crossbar::pack`] re-chooses the format once programming is done).
    pub fn zeros(rows: usize, cols: usize) -> Self {
        assert!(rows <= XBAR_ROWS && cols <= XBAR_COLS, "{rows}x{cols}");
        Crossbar {
            store: CellArray::Dense(vec![0; rows * cols]),
            rows,
            cols,
            nonzero: 0,
        }
    }

    /// Build a tile from its programmed cells `(row, col, val)` — the
    /// mapper's path. The format is chosen up front from the cell count
    /// ([`chosen_format`]), so sparse tiles go straight to compressed
    /// storage with **no dense intermediate**. Cells may arrive in any
    /// order; values must be non-zero and positions unique.
    pub fn from_cells(rows: usize, cols: usize, mut cells: Vec<(u16, u16, u8)>) -> Self {
        assert!(rows <= XBAR_ROWS && cols <= XBAR_COLS, "{rows}x{cols}");
        cells.sort_unstable();
        for pair in cells.windows(2) {
            assert!(
                (pair[0].0, pair[0].1) != (pair[1].0, pair[1].1),
                "duplicate cell ({}, {})",
                pair[0].0,
                pair[0].1
            );
        }
        let nonzero = cells.len();
        let store = match chosen_format(nonzero, rows, cols) {
            StorageFormat::Dense => {
                let mut data = vec![0u8; rows * cols];
                for &(r, c, v) in &cells {
                    Self::check_cell(rows, cols, r as usize, c as usize, v);
                    data[r as usize * cols + c as usize] = v;
                }
                CellArray::Dense(data)
            }
            StorageFormat::Compressed => {
                for &(r, c, v) in &cells {
                    Self::check_cell(rows, cols, r as usize, c as usize, v);
                }
                build_compressed(rows, cols, cells.iter().map(|&(r, c, v)| (r as usize, c, v)))
            }
            StorageFormat::BitPlanes => {
                for &(r, c, v) in &cells {
                    Self::check_cell(rows, cols, r as usize, c as usize, v);
                }
                build_bitplanes(rows, cols, cells.iter().map(|&(r, c, v)| (r as usize, c, v)))
            }
        };
        Crossbar {
            store,
            rows,
            cols,
            nonzero,
        }
    }

    fn check_cell(rows: usize, cols: usize, r: usize, c: usize, v: u8) {
        assert!(r < rows && c < cols, "cell ({r},{c}) outside {rows}x{cols}");
        assert!((1..=CELL_MAX).contains(&v), "cell value {v}");
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The current storage layout.
    pub fn format(&self) -> StorageFormat {
        match self.store {
            CellArray::Dense(_) => StorageFormat::Dense,
            CellArray::Compressed { .. } => StorageFormat::Compressed,
            CellArray::BitPlanes { .. } => StorageFormat::BitPlanes,
        }
    }

    /// Programmed fraction of the tile's cells.
    pub fn density(&self) -> f64 {
        let cells = self.rows * self.cols;
        if cells == 0 {
            0.0
        } else {
            self.nonzero as f64 / cells as f64
        }
    }

    /// Heap bytes the cell storage occupies under the current format.
    pub fn storage_bytes(&self) -> usize {
        match &self.store {
            CellArray::Dense(cells) => cells.len(),
            CellArray::Compressed {
                row_ptr,
                entry_cols,
                entry_vals,
                active_rows,
                active_cols,
            } => {
                entry_cols.len() * std::mem::size_of::<u16>()
                    + entry_vals.len()
                    + row_ptr.len() * std::mem::size_of::<u32>()
                    + active_rows.len() * std::mem::size_of::<u16>()
                    + active_cols.len() * std::mem::size_of::<u16>()
            }
            CellArray::BitPlanes {
                plane0,
                plane1,
                active_cols,
            } => {
                (plane0.len() + plane1.len()) * std::mem::size_of::<[u64; 2]>()
                    + active_cols.len() * std::mem::size_of::<u16>()
            }
        }
    }

    /// Program one cell, maintaining the cached census. Works in either
    /// representation — compressed updates splice the entry list, which is
    /// fine off the hot path (programming happens once, at map time).
    pub fn set(&mut self, r: usize, c: usize, v: u8) {
        assert!(v <= CELL_MAX, "cell value {v}");
        assert!(
            r < self.rows && c < self.cols,
            "cell ({r},{c}) outside {}x{}",
            self.rows,
            self.cols
        );
        match &mut self.store {
            CellArray::Dense(cells) => {
                let cell = &mut cells[r * self.cols + c];
                self.nonzero += (v != 0) as usize;
                self.nonzero -= (*cell != 0) as usize;
                *cell = v;
            }
            CellArray::Compressed {
                row_ptr,
                entry_cols,
                entry_vals,
                active_rows,
                active_cols,
            } => {
                let lo = row_ptr[r] as usize;
                let hi = row_ptr[r + 1] as usize;
                match entry_cols[lo..hi].binary_search(&(c as u16)) {
                    Ok(i) if v != 0 => entry_vals[lo + i] = v,
                    Ok(i) => {
                        // clearing the row's only entry deactivates it
                        entry_cols.remove(lo + i);
                        entry_vals.remove(lo + i);
                        for p in row_ptr[r + 1..].iter_mut() {
                            *p -= 1;
                        }
                        if hi - lo == 1 {
                            if let Ok(a) = active_rows.binary_search(&(r as u16)) {
                                active_rows.remove(a);
                            }
                        }
                        // deactivate the column once no other row holds it
                        // (the membership scan is O(entries) — fine off
                        // the hot path; programming happens at map time)
                        if !entry_cols.contains(&(c as u16)) {
                            if let Ok(a) = active_cols.binary_search(&(c as u16)) {
                                active_cols.remove(a);
                            }
                        }
                        self.nonzero -= 1;
                    }
                    Err(_) if v == 0 => {}
                    Err(i) => {
                        entry_cols.insert(lo + i, c as u16);
                        entry_vals.insert(lo + i, v);
                        for p in row_ptr[r + 1..].iter_mut() {
                            *p += 1;
                        }
                        if hi == lo {
                            if let Err(a) = active_rows.binary_search(&(r as u16)) {
                                active_rows.insert(a, r as u16);
                            }
                        }
                        if let Err(a) = active_cols.binary_search(&(c as u16)) {
                            active_cols.insert(a, c as u16);
                        }
                        self.nonzero += 1;
                    }
                }
            }
            CellArray::BitPlanes {
                plane0,
                plane1,
                active_cols,
            } => {
                let (w, b) = (r >> 6, r & 63);
                let old = (((plane1[c][w] >> b) & 1) << 1) | ((plane0[c][w] >> b) & 1);
                plane0[c][w] = (plane0[c][w] & !(1 << b)) | (((v & 1) as u64) << b);
                plane1[c][w] = (plane1[c][w] & !(1 << b)) | ((((v >> 1) & 1) as u64) << b);
                self.nonzero += (v != 0) as usize;
                self.nonzero -= (old != 0) as usize;
                // keep the nonzero-column index exact: the column is live
                // iff any plane word still holds a bit
                let live = (plane0[c][0] | plane0[c][1] | plane1[c][0] | plane1[c][1]) != 0;
                match (live, active_cols.binary_search(&(c as u16))) {
                    (true, Err(i)) => active_cols.insert(i, c as u16),
                    (false, Ok(i)) => {
                        active_cols.remove(i);
                    }
                    _ => {}
                }
            }
        }
    }

    pub fn get(&self, r: usize, c: usize) -> u8 {
        assert!(
            r < self.rows && c < self.cols,
            "cell ({r},{c}) outside {}x{}",
            self.rows,
            self.cols
        );
        match &self.store {
            CellArray::Dense(cells) => cells[r * self.cols + c],
            CellArray::Compressed {
                row_ptr,
                entry_cols,
                entry_vals,
                ..
            } => {
                let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
                match entry_cols[lo..hi].binary_search(&(c as u16)) {
                    Ok(i) => entry_vals[lo + i],
                    Err(_) => 0,
                }
            }
            CellArray::BitPlanes { plane0, plane1, .. } => {
                let (w, b) = (r >> 6, r & 63);
                ((((plane1[c][w] >> b) & 1) << 1) | ((plane0[c][w] >> b) & 1)) as u8
            }
        }
    }

    /// Number of programmed (non-zero) cells — the mapped-sparsity census,
    /// cached at program time (O(1), never a recount).
    pub fn nonzero_cells(&self) -> usize {
        self.nonzero
    }

    /// The programmed cells as row-major `(row, col, val)` triples (row
    /// ascending, column ascending within a row) — the layout-neutral
    /// interchange form `convert` rebuilds any representation from (and
    /// [`super::device`] derives per-cell perturbations from: the triple
    /// order is identical across layouts, so a seeded noise draw per
    /// physical cell cannot depend on the storage representation).
    pub(crate) fn triples(&self) -> Vec<(usize, u16, u8)> {
        let mut out = Vec::with_capacity(self.nonzero);
        match &self.store {
            CellArray::Dense(cells) => {
                for r in 0..self.rows {
                    for c in 0..self.cols {
                        let v = cells[r * self.cols + c];
                        if v != 0 {
                            out.push((r, c as u16, v));
                        }
                    }
                }
            }
            CellArray::Compressed {
                row_ptr,
                entry_cols,
                entry_vals,
                ..
            } => {
                for r in 0..self.rows {
                    for i in row_ptr[r] as usize..row_ptr[r + 1] as usize {
                        out.push((r, entry_cols[i], entry_vals[i]));
                    }
                }
            }
            CellArray::BitPlanes { plane0, plane1, .. } => {
                for r in 0..self.rows {
                    let (w, b) = (r >> 6, r & 63);
                    for c in 0..self.cols {
                        let v = ((((plane1[c][w] >> b) & 1) << 1) | ((plane0[c][w] >> b) & 1)) as u8;
                        if v != 0 {
                            out.push((r, c as u16, v));
                        }
                    }
                }
            }
        }
        out
    }

    /// Re-lay the cells out in `fmt` (no-op when already there).
    pub fn convert(&mut self, fmt: StorageFormat) {
        if self.format() == fmt {
            return;
        }
        let (rows, cols) = (self.rows, self.cols);
        let triples = self.triples();
        self.store = match fmt {
            StorageFormat::Dense => {
                let mut data = vec![0u8; rows * cols];
                for &(r, c, v) in &triples {
                    data[r * cols + c as usize] = v;
                }
                CellArray::Dense(data)
            }
            StorageFormat::Compressed => build_compressed(rows, cols, triples.into_iter()),
            StorageFormat::BitPlanes => build_bitplanes(rows, cols, triples.into_iter()),
        };
    }

    /// A clone laid out in `fmt` — the benches' and the representation
    /// property tests' handle for comparing both paths on identical cells.
    pub fn in_format(&self, fmt: StorageFormat) -> Crossbar {
        let mut xb = self.clone();
        xb.convert(fmt);
        xb
    }

    /// Choose the storage format from the measured density (the
    /// [`chosen_format`] band policy) — call once programming is
    /// complete.
    pub fn pack(&mut self) {
        self.convert(chosen_format(self.nonzero, self.rows, self.cols));
    }

    /// Per-column sum of conductances: the worst-case bitline current
    /// (every wordline driving a '1'), in LSB units.
    pub fn column_conductance_sums(&self) -> Vec<u32> {
        let mut sums = vec![0u32; self.cols];
        match &self.store {
            CellArray::Dense(cells) => {
                for r in 0..self.rows {
                    let row = &cells[r * self.cols..(r + 1) * self.cols];
                    for (s, &v) in sums.iter_mut().zip(row) {
                        *s += v as u32;
                    }
                }
            }
            CellArray::Compressed {
                entry_cols,
                entry_vals,
                ..
            } => {
                for (&c, &v) in entry_cols.iter().zip(entry_vals) {
                    sums[c as usize] += v as u32;
                }
            }
            CellArray::BitPlanes { plane0, plane1, .. } => {
                for (s, (p0, p1)) in sums.iter_mut().zip(plane0.iter().zip(plane1)) {
                    *s = p0[0].count_ones()
                        + p0[1].count_ones()
                        + ((p1[0].count_ones() + p1[1].count_ones()) << 1);
                }
            }
        }
        sums
    }

    /// Wordlines holding >= 1 programmed cell — the rows the sparse
    /// current scan visits. O(1) in the compressed layout (the cached
    /// nonzero-wordline index); a recount in the dense layout and a
    /// cheap per-column OR in the bit-plane layout (stats paths only,
    /// never the hot loop).
    pub fn active_wordlines(&self) -> usize {
        match &self.store {
            CellArray::Dense(cells) => (0..self.rows)
                .filter(|&r| cells[r * self.cols..(r + 1) * self.cols].iter().any(|&v| v != 0))
                .count(),
            CellArray::Compressed { active_rows, .. } => active_rows.len(),
            CellArray::BitPlanes { plane0, plane1, .. } => {
                let mut live = [0u64; 2];
                for (p0, p1) in plane0.iter().zip(plane1) {
                    live[0] |= p0[0] | p1[0];
                    live[1] |= p0[1] | p1[1];
                }
                (live[0].count_ones() + live[1].count_ones()) as usize
            }
        }
    }

    /// Output columns holding >= 1 programmed cell — the columns whose
    /// ADC actually converts (structurally-zero columns are skipped, see
    /// [`Self::bitline_currents_active`]). O(1) in the compressed layout;
    /// a recount in the dense layout (stats paths only).
    pub fn active_columns(&self) -> usize {
        match &self.store {
            CellArray::Dense(cells) => {
                let mut seen = vec![false; self.cols];
                for r in 0..self.rows {
                    let row = &cells[r * self.cols..(r + 1) * self.cols];
                    for (s, &v) in seen.iter_mut().zip(row) {
                        *s |= v != 0;
                    }
                }
                seen.iter().filter(|&&s| s).count()
            }
            CellArray::Compressed { active_cols, .. } => active_cols.len(),
            CellArray::BitPlanes { active_cols, .. } => active_cols.len(),
        }
    }

    /// The nonzero-column index (ascending), when the layout caches one:
    /// `Some` for compressed and bit-plane tiles, `None` for dense ones.
    /// A column outside the index holds no programmed cell and can never
    /// carry current.
    pub fn active_cols(&self) -> Option<&[u16]> {
        match &self.store {
            CellArray::Dense(_) => None,
            CellArray::Compressed { active_cols, .. } => Some(active_cols),
            CellArray::BitPlanes { active_cols, .. } => Some(active_cols),
        }
    }

    /// Columns whose ADC actually converts under this layout — what the
    /// energy model bills and the resolution census counts. Compressed
    /// and bit-plane tiles convert only their nonzero-column index; dense
    /// tiles carry no index, so every column converts (matching the dense
    /// branch of the simulator's ADC loop exactly). O(1) in every layout.
    pub fn converting_columns(&self) -> usize {
        match &self.store {
            CellArray::Dense(_) => self.cols,
            CellArray::Compressed { active_cols, .. } => active_cols.len(),
            CellArray::BitPlanes { active_cols, .. } => active_cols.len(),
        }
    }

    /// Accumulate one bit-plane's currents into `out` (no zeroing — the
    /// callers own the reset policy).
    fn accumulate_currents(&self, bits: &[u8], out: &mut [u32]) {
        match &self.store {
            CellArray::Dense(cells) => {
                for (r, &b) in bits.iter().enumerate() {
                    if b == 0 {
                        continue;
                    }
                    let row = &cells[r * self.cols..(r + 1) * self.cols];
                    for (o, &v) in out.iter_mut().zip(row) {
                        *o += v as u32;
                    }
                }
            }
            CellArray::Compressed {
                row_ptr,
                entry_cols,
                entry_vals,
                active_rows,
                ..
            } => {
                // touch only programmed cells on active wordlines
                for &r in active_rows {
                    let r = r as usize;
                    if bits[r] == 0 {
                        continue;
                    }
                    let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
                    for (&c, &v) in entry_cols[lo..hi].iter().zip(&entry_vals[lo..hi]) {
                        out[c as usize] += v as u32;
                    }
                }
            }
            // the popcount layout has no byte path: pack and take it
            CellArray::BitPlanes { .. } => self.accumulate_currents_wave(&pack_wave(bits), out),
        }
    }

    /// Wave-mask twin of [`Self::accumulate_currents`]: one bit-plane's
    /// currents from the packed `[u64; 2]` wordline mask. In the
    /// bit-plane layout this is the popcount hot path — ~4 word ops per
    /// active column; the byte layouts unpack the wave bit-by-row so
    /// every representation answers the same wave bit-exactly.
    fn accumulate_currents_wave(&self, wave: &[u64; 2], out: &mut [u32]) {
        match &self.store {
            CellArray::BitPlanes {
                plane0,
                plane1,
                active_cols,
            } => {
                for &c in active_cols {
                    let c = c as usize;
                    let (p0, p1) = (plane0[c], plane1[c]);
                    let ones = (p0[0] & wave[0]).count_ones() + (p0[1] & wave[1]).count_ones();
                    let twos = (p1[0] & wave[0]).count_ones() + (p1[1] & wave[1]).count_ones();
                    out[c] += ones + (twos << 1);
                }
            }
            CellArray::Dense(cells) => {
                for r in 0..self.rows {
                    if (wave[r >> 6] >> (r & 63)) & 1 == 0 {
                        continue;
                    }
                    let row = &cells[r * self.cols..(r + 1) * self.cols];
                    for (o, &v) in out.iter_mut().zip(row) {
                        *o += v as u32;
                    }
                }
            }
            CellArray::Compressed {
                row_ptr,
                entry_cols,
                entry_vals,
                active_rows,
                ..
            } => {
                for &r in active_rows {
                    let r = r as usize;
                    if (wave[r >> 6] >> (r & 63)) & 1 == 0 {
                        continue;
                    }
                    let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
                    for (&c, &v) in entry_cols[lo..hi].iter().zip(&entry_vals[lo..hi]) {
                        out[c as usize] += v as u32;
                    }
                }
            }
        }
    }

    /// Hard-assert the wave drives no wordline at or beyond `self.rows`.
    /// Every layout would ignore such bits (the scans are row-bounded and
    /// the plane masks hold no high bits), so a stray bit is always a
    /// caller packing bug — surfaced here rather than silently dropped,
    /// mirroring the byte path's `bits.len()` hard assert.
    fn check_wave(&self, wave: &[u64; 2]) {
        let excess = if self.rows >= 128 {
            0
        } else if self.rows >= 64 {
            wave[1] >> (self.rows - 64)
        } else {
            (wave[0] >> self.rows) | wave[1]
        };
        assert_eq!(excess, 0, "wave drives wordlines beyond row {}", self.rows);
    }

    /// Bitline currents for one input bit-plane (`bits[r]` in {0,1}).
    /// Every slot of `out` is written (zeroed, then accumulated).
    ///
    /// The buffer lengths are hard asserts in **both** representations and
    /// all build profiles: a short `out` would silently truncate the `zip`
    /// accumulation in release builds if only debug-asserted, and a short
    /// `bits` would drop wordlines.
    pub fn bitline_currents(&self, bits: &[u8], out: &mut [u32]) {
        assert_eq!(bits.len(), self.rows, "input bit-plane length");
        assert_eq!(out.len(), self.cols, "bitline current buffer length");
        out.fill(0);
        self.accumulate_currents(bits, out);
    }

    /// Sparse variant of [`Self::bitline_currents`] for the per-tile ADC
    /// loop: in the indexed layouts (compressed, bit-planes), only
    /// **active** columns of `out` are zeroed and accumulated — slots of
    /// structurally-zero columns are neither written nor meaningful
    /// afterwards — and the cached nonzero-column index is returned so
    /// the caller converts exactly those columns. In the dense layout
    /// this is `bitline_currents` (every slot valid) and the index is
    /// `None`. Same hard length asserts as the full variant.
    pub fn bitline_currents_active(&self, bits: &[u8], out: &mut [u32]) -> Option<&[u16]> {
        assert_eq!(bits.len(), self.rows, "input bit-plane length");
        assert_eq!(out.len(), self.cols, "bitline current buffer length");
        match &self.store {
            CellArray::Compressed { active_cols, .. }
            | CellArray::BitPlanes { active_cols, .. } => {
                for &c in active_cols {
                    out[c as usize] = 0;
                }
                self.accumulate_currents(bits, out);
                Some(active_cols)
            }
            CellArray::Dense(_) => {
                out.fill(0);
                self.accumulate_currents(bits, out);
                None
            }
        }
    }

    /// Wave-mask twin of [`Self::bitline_currents_active`], for callers
    /// that already hold the bit-plane packed as a `[u64; 2]` wordline
    /// mask (bit `r % 64` of word `r / 64` drives wordline `r`). On a
    /// bit-plane tile this is the popcount hot path; the byte layouts
    /// unpack the wave per row, so all three answer bit-exactly. Same
    /// active-column contract: indexed layouts zero and fill only active
    /// slots and return the index, the dense layout fills every slot and
    /// returns `None`. Hard asserts: `out` length, and no wave bit at or
    /// beyond `rows`.
    pub fn bitline_currents_wave(&self, wave: &[u64; 2], out: &mut [u32]) -> Option<&[u16]> {
        assert_eq!(out.len(), self.cols, "bitline current buffer length");
        self.check_wave(wave);
        match &self.store {
            CellArray::Compressed { active_cols, .. }
            | CellArray::BitPlanes { active_cols, .. } => {
                for &c in active_cols {
                    out[c as usize] = 0;
                }
                self.accumulate_currents_wave(wave, out);
                Some(active_cols)
            }
            CellArray::Dense(_) => {
                out.fill(0);
                self.accumulate_currents_wave(wave, out);
                None
            }
        }
    }

    /// Structural self-check of the tile's storage: re-derives every
    /// cached quantity (census, CSR offsets, active indexes, plane
    /// padding) from the raw cell data and reports each disagreement as
    /// a [`TileFault`]. Read-only — `reram::audit` turns the faults into
    /// typed diagnostics; a clean tile returns an empty list.
    pub(crate) fn verify_cells(&self) -> Vec<TileFault> {
        let mut faults = Vec::new();
        match &self.store {
            CellArray::Dense(cells) => {
                if cells.len() != self.rows * self.cols {
                    faults.push(TileFault::IndexInconsistent(format!(
                        "dense store holds {} cells for a {}x{} tile",
                        cells.len(),
                        self.rows,
                        self.cols
                    )));
                    return faults;
                }
                let mut actual = 0usize;
                for r in 0..self.rows {
                    for c in 0..self.cols {
                        let v = cells[r * self.cols + c];
                        if v > CELL_MAX {
                            faults.push(TileFault::ValueOutOfRange { row: r, col: c, value: v });
                        }
                        actual += (v != 0) as usize;
                    }
                }
                if actual != self.nonzero {
                    faults.push(TileFault::CensusMismatch {
                        cached: self.nonzero,
                        actual,
                    });
                }
            }
            CellArray::Compressed {
                row_ptr,
                entry_cols,
                entry_vals,
                active_rows,
                active_cols,
            } => {
                if row_ptr.len() != self.rows + 1 {
                    faults.push(TileFault::IndexInconsistent(format!(
                        "row_ptr holds {} offsets for {} rows",
                        row_ptr.len(),
                        self.rows
                    )));
                    return faults;
                }
                if entry_cols.len() != entry_vals.len() {
                    faults.push(TileFault::IndexInconsistent(format!(
                        "{} entry columns vs {} entry values",
                        entry_cols.len(),
                        entry_vals.len()
                    )));
                    return faults;
                }
                if (0..self.rows).any(|r| row_ptr[r] > row_ptr[r + 1]) {
                    faults.push(TileFault::IndexInconsistent(
                        "row_ptr offsets decrease".into(),
                    ));
                    return faults;
                }
                if row_ptr[0] != 0 || row_ptr[self.rows] as usize != entry_cols.len() {
                    faults.push(TileFault::IndexInconsistent(format!(
                        "row_ptr spans {}..{} over {} entries",
                        row_ptr[0],
                        row_ptr[self.rows],
                        entry_cols.len()
                    )));
                    return faults;
                }
                let mut want_rows: Vec<u16> = Vec::new();
                let mut col_seen = vec![false; self.cols];
                for r in 0..self.rows {
                    let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
                    if hi > lo {
                        want_rows.push(r as u16);
                    }
                    for i in lo..hi {
                        let (c, v) = (entry_cols[i] as usize, entry_vals[i]);
                        if c >= self.cols {
                            faults.push(TileFault::IndexInconsistent(format!(
                                "row {r} entry column {c} outside {} columns",
                                self.cols
                            )));
                            continue;
                        }
                        if i > lo && entry_cols[i - 1] >= entry_cols[i] {
                            faults.push(TileFault::IndexInconsistent(format!(
                                "row {r} entry columns not strictly ascending at column {c}"
                            )));
                        }
                        if !(1..=CELL_MAX).contains(&v) {
                            faults.push(TileFault::ValueOutOfRange { row: r, col: c, value: v });
                        }
                        col_seen[c] = true;
                    }
                }
                let want_cols: Vec<u16> = (0..self.cols)
                    .filter(|&c| col_seen[c])
                    .map(|c| c as u16)
                    .collect();
                if active_rows != &want_rows {
                    faults.push(TileFault::IndexInconsistent(format!(
                        "active-wordline index holds {} rows, entries span {}",
                        active_rows.len(),
                        want_rows.len()
                    )));
                }
                if active_cols != &want_cols {
                    faults.push(TileFault::IndexInconsistent(format!(
                        "active-column index holds {} columns, entries span {}",
                        active_cols.len(),
                        want_cols.len()
                    )));
                }
                if entry_cols.len() != self.nonzero {
                    faults.push(TileFault::CensusMismatch {
                        cached: self.nonzero,
                        actual: entry_cols.len(),
                    });
                }
            }
            CellArray::BitPlanes {
                plane0,
                plane1,
                active_cols,
            } => {
                if plane0.len() != self.cols || plane1.len() != self.cols {
                    faults.push(TileFault::PlaneMaskInconsistent(format!(
                        "{}/{} plane columns for a {}-column tile",
                        plane0.len(),
                        plane1.len(),
                        self.cols
                    )));
                    return faults;
                }
                // valid-row masks: rows >= self.rows are zero padding by
                // the packing convention
                let (mask0, mask1) = if self.rows >= 128 {
                    (!0u64, !0u64)
                } else if self.rows >= 64 {
                    (!0u64, (1u64 << (self.rows - 64)) - 1)
                } else {
                    ((1u64 << self.rows) - 1, 0u64)
                };
                let mut actual = 0usize;
                let mut want_cols: Vec<u16> = Vec::new();
                for c in 0..self.cols {
                    let (p0, p1) = (plane0[c], plane1[c]);
                    if (p0[0] & !mask0) | (p0[1] & !mask1) | (p1[0] & !mask0) | (p1[1] & !mask1)
                        != 0
                    {
                        faults.push(TileFault::PlaneMaskInconsistent(format!(
                            "column {c} holds plane bits beyond row {}",
                            self.rows
                        )));
                    }
                    let live = ((p0[0] | p1[0]) & mask0).count_ones()
                        + ((p0[1] | p1[1]) & mask1).count_ones();
                    actual += live as usize;
                    if live > 0 {
                        want_cols.push(c as u16);
                    }
                }
                if active_cols != &want_cols {
                    faults.push(TileFault::PlaneMaskInconsistent(format!(
                        "active-column index holds {} columns, plane masks light {}",
                        active_cols.len(),
                        want_cols.len()
                    )));
                }
                if actual != self.nonzero {
                    faults.push(TileFault::CensusMismatch {
                        cached: self.nonzero,
                        actual,
                    });
                }
            }
        }
        faults
    }
}

/// Test-only corruption hooks: poke raw storage fields *past* the safe
/// mutators so the audit property tests can plant each fault class
/// ([`Crossbar::set`] and the builders maintain every invariant, so a
/// planted violation needs a back door). Each panics when the tile is
/// not in the layout it targets.
#[cfg(any(test, feature = "bench"))]
impl Crossbar {
    /// Desync the cached nonzero census from the store.
    pub fn corrupt_census(&mut self, delta: isize) {
        self.nonzero = self.nonzero.wrapping_add_signed(delta);
    }

    /// Raw write into the dense byte array, bypassing the value-range
    /// check and the census bookkeeping.
    pub fn corrupt_dense_value(&mut self, r: usize, c: usize, v: u8) {
        match &mut self.store {
            CellArray::Dense(cells) => cells[r * self.cols + c] = v,
            _ => panic!("corrupt_dense_value wants the dense layout"),
        }
    }

    /// Flip one low-plane mask bit, bypassing census and column-index
    /// maintenance.
    pub fn corrupt_flip_plane_bit(&mut self, r: usize, c: usize) {
        match &mut self.store {
            CellArray::BitPlanes { plane0, .. } => plane0[c][r >> 6] ^= 1 << (r & 63),
            _ => panic!("corrupt_flip_plane_bit wants the bit-plane layout"),
        }
    }

    /// Rewrite one compressed entry's column, bypassing the ordering and
    /// active-index maintenance.
    pub fn corrupt_entry_col(&mut self, i: usize, col: u16) {
        match &mut self.store {
            CellArray::Compressed { entry_cols, .. } => entry_cols[i] = col,
            _ => panic!("corrupt_entry_col wants the compressed layout"),
        }
    }

    /// Drop the last entry of the nonzero-column index (compressed or
    /// bit-plane layout) — the column still holds programmed cells, but
    /// the ADC/energy/timing accounting no longer sees it.
    pub fn corrupt_drop_active_col(&mut self) -> Option<u16> {
        match &mut self.store {
            CellArray::Compressed { active_cols, .. }
            | CellArray::BitPlanes { active_cols, .. } => active_cols.pop(),
            CellArray::Dense(_) => panic!("corrupt_drop_active_col wants an indexed layout"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, ensure};

    #[test]
    fn geometry_limits_enforced() {
        let xb = Crossbar::zeros(128, 128);
        assert_eq!((xb.rows(), xb.cols()), (128, 128));
        assert_eq!(xb.format(), StorageFormat::Dense);
    }

    #[test]
    #[should_panic]
    fn oversized_array_panics() {
        let _ = Crossbar::zeros(129, 10);
    }

    #[test]
    #[should_panic]
    fn cell_value_range_enforced() {
        let mut xb = Crossbar::zeros(2, 2);
        xb.set(0, 0, 4);
    }

    #[test]
    #[should_panic]
    fn short_current_buffer_panics_in_every_profile() {
        // a short `out` used to truncate silently in release builds
        let xb = Crossbar::zeros(4, 4);
        let mut out = vec![0u32; 3];
        xb.bitline_currents(&[1, 1, 1, 1], &mut out);
    }

    #[test]
    #[should_panic]
    fn short_bit_plane_panics() {
        let xb = Crossbar::zeros(4, 4);
        let mut out = vec![0u32; 4];
        xb.bitline_currents(&[1, 1, 1], &mut out);
    }

    #[test]
    fn column_sums_and_currents_agree_for_all_ones_input() {
        check(25, |rng| {
            let rows = 1 + rng.below(128);
            let cols = 1 + rng.below(128);
            let mut xb = Crossbar::zeros(rows, cols);
            for r in 0..rows {
                for c in 0..cols {
                    xb.set(r, c, rng.below(4) as u8);
                }
            }
            let bits = vec![1u8; rows];
            let mut cur = vec![0u32; cols];
            xb.bitline_currents(&bits, &mut cur);
            ensure(
                cur == xb.column_conductance_sums(),
                "all-ones currents == column sums",
            )?;
            Ok(())
        });
    }

    #[test]
    fn currents_respect_input_bits() {
        let mut xb = Crossbar::zeros(3, 2);
        xb.set(0, 0, 3);
        xb.set(1, 0, 2);
        xb.set(2, 1, 1);
        let mut cur = vec![0u32; 2];
        xb.bitline_currents(&[1, 0, 1], &mut cur);
        assert_eq!(cur, vec![3, 1]);
        // identical answers from the other layouts
        for fmt in [StorageFormat::Compressed, StorageFormat::BitPlanes] {
            let other = xb.in_format(fmt);
            other.bitline_currents(&[1, 0, 1], &mut cur);
            assert_eq!(cur, vec![3, 1], "{fmt:?}");
        }
    }

    #[test]
    fn nonzero_cell_census() {
        let mut xb = Crossbar::zeros(4, 4);
        assert_eq!(xb.nonzero_cells(), 0);
        xb.set(1, 2, 2);
        xb.set(3, 3, 1);
        assert_eq!(xb.nonzero_cells(), 2);
        // the cache tracks overwrites and clears, not just first writes
        xb.set(1, 2, 3);
        assert_eq!(xb.nonzero_cells(), 2);
        xb.set(3, 3, 0);
        assert_eq!(xb.nonzero_cells(), 1);
        xb.set(3, 3, 0);
        assert_eq!(xb.nonzero_cells(), 1);
    }

    const ALL_FORMATS: [StorageFormat; 3] = [
        StorageFormat::Dense,
        StorageFormat::Compressed,
        StorageFormat::BitPlanes,
    ];

    /// `pack_code_wave(codes, t)` is `pack_wave` of the extracted byte
    /// plane, for every bit position and ragged wordline counts.
    #[test]
    fn pack_code_wave_matches_byte_plane_packing() {
        let mut rng = Rng::new(91);
        for rows in [1usize, 63, 64, 65, 127, XBAR_ROWS] {
            let codes: Vec<u8> = (0..rows).map(|_| rng.below(256) as u8).collect();
            for t in 0..8u32 {
                let plane: Vec<u8> = codes.iter().map(|&c| (c >> t) & 1).collect();
                assert_eq!(pack_code_wave(&codes, t), pack_wave(&plane), "rows {rows} bit {t}");
            }
        }
    }

    /// Property: all three layouts agree bit-exactly, pairwise, on every
    /// read path — census, column sums, byte-plane currents, wave-mask
    /// currents, cell reads after a round trip — across random densities
    /// and partial-tile geometries.
    #[test]
    fn representations_agree_bit_exactly() {
        check(40, |rng| {
            let rows = 1 + rng.below(XBAR_ROWS);
            let cols = 1 + rng.below(XBAR_COLS);
            // fill in 0..=100 percent: hits near-empty and near-full tiles
            let fill = rng.below(101);
            let mut dense = Crossbar::zeros(rows, cols);
            for r in 0..rows {
                for c in 0..cols {
                    if rng.below(100) < fill {
                        dense.set(r, c, 1 + rng.below(3) as u8);
                    }
                }
            }
            let bits: Vec<u8> = (0..rows).map(|_| rng.below(2) as u8).collect();
            let wave = pack_wave(&bits);
            let layouts: Vec<Crossbar> = ALL_FORMATS.iter().map(|&f| dense.in_format(f)).collect();
            let mut cur: Vec<Vec<u32>> = Vec::new();
            for (xb, fmt) in layouts.iter().zip(ALL_FORMATS) {
                ensure(xb.format() == fmt, "converted")?;
                ensure(xb.nonzero_cells() == dense.nonzero_cells(), "census")?;
                ensure(
                    xb.column_conductance_sums() == dense.column_conductance_sums(),
                    "column sums",
                )?;
                let mut a = vec![0u32; cols];
                xb.bitline_currents(&bits, &mut a);
                let mut w = vec![0u32; cols];
                xb.bitline_currents_wave(&wave, &mut w);
                ensure(a == w, format!("{fmt:?} byte plane vs wave"))?;
                cur.push(a);
                // round-trip back to dense preserves every cell
                let back = xb.in_format(StorageFormat::Dense);
                for r in 0..rows {
                    for c in 0..cols {
                        ensure(back.get(r, c) == dense.get(r, c), "round-trip cell")?;
                    }
                }
            }
            for pair in cur.windows(2) {
                ensure(pair[0] == pair[1], "pairwise bitline currents")?;
            }
            Ok(())
        });
    }

    /// Property: `set` on an indexed tile (update / insert / clear)
    /// tracks a dense mirror exactly, census included — for both the
    /// compressed and the bit-plane layout.
    #[test]
    fn indexed_set_matches_dense_mirror() {
        for fmt in [StorageFormat::Compressed, StorageFormat::BitPlanes] {
            check(30, |rng| {
                let rows = 1 + rng.below(XBAR_ROWS);
                let cols = 1 + rng.below(XBAR_COLS);
                let mut dense = Crossbar::zeros(rows, cols);
                let mut other = Crossbar::zeros(rows, cols).in_format(fmt);
                for _ in 0..200 {
                    let (r, c) = (rng.below(rows), rng.below(cols));
                    let v = rng.below(4) as u8; // 0 = clear
                    dense.set(r, c, v);
                    other.set(r, c, v);
                }
                ensure(
                    other.nonzero_cells() == dense.nonzero_cells(),
                    "census after mutation",
                )?;
                for r in 0..rows {
                    for c in 0..cols {
                        ensure(other.get(r, c) == dense.get(r, c), "cell after mutation")?;
                    }
                }
                let bits = vec![1u8; rows];
                let mut a = vec![0u32; cols];
                let mut b = vec![0u32; cols];
                dense.bitline_currents(&bits, &mut a);
                other.bitline_currents(&bits, &mut b);
                ensure(a == b, "currents after mutation")?;
                Ok(())
            });
        }
    }

    #[test]
    fn format_edges_all_zero_and_fully_dense() {
        for fmt in [StorageFormat::Compressed, StorageFormat::BitPlanes] {
            // all-zero tile: the indexed layouts hold no entries, read zeros
            let z = Crossbar::zeros(5, 7).in_format(fmt);
            assert_eq!(z.nonzero_cells(), 0);
            assert_eq!(z.density(), 0.0);
            let mut cur = vec![9u32; 7];
            z.bitline_currents(&[1; 5], &mut cur);
            assert!(cur.iter().all(|&v| v == 0), "{fmt:?}");
            assert_eq!(z.get(4, 6), 0);

            // fully-dense tile survives the layout detour bit-exactly
            let mut full = Crossbar::zeros(3, 4);
            for r in 0..3 {
                for c in 0..4 {
                    full.set(r, c, CELL_MAX);
                }
            }
            let fc = full.in_format(fmt);
            assert_eq!(fc.nonzero_cells(), 12);
            assert_eq!(fc.density(), 1.0);
            assert_eq!(fc.column_conductance_sums(), full.column_conductance_sums());
        }
    }

    #[test]
    fn from_cells_picks_format_by_density() {
        // 2 of 16 cells (12.5%) -> compressed, built with no dense pass
        let sparse = Crossbar::from_cells(4, 4, vec![(3, 3, 1), (0, 1, 2)]);
        assert_eq!(sparse.format(), StorageFormat::Compressed);
        assert_eq!(sparse.nonzero_cells(), 2);
        assert_eq!(sparse.get(0, 1), 2);
        assert_eq!(sparse.get(3, 3), 1);
        assert_eq!(sparse.get(1, 1), 0);

        // 8 of 16 cells (50%) -> the mid band, packed bit-planes
        let cells: Vec<(u16, u16, u8)> = (0u16..8).map(|i| (i / 4, i % 4, 3u8)).collect();
        let mid = Crossbar::from_cells(4, 4, cells);
        assert_eq!(mid.format(), StorageFormat::BitPlanes);
        assert_eq!(mid.nonzero_cells(), 8);
        for i in 0u16..8 {
            assert_eq!(mid.get((i / 4) as usize, (i % 4) as usize), 3);
        }

        // 12 of 16 cells (75%) -> dense
        let cells: Vec<(u16, u16, u8)> = (0u16..12).map(|i| (i / 4, i % 4, 3u8)).collect();
        let dense = Crossbar::from_cells(4, 4, cells);
        assert_eq!(dense.format(), StorageFormat::Dense);
        assert_eq!(dense.nonzero_cells(), 12);

        // pack() applies the same band policy to an already-built tile
        let mut xb = Crossbar::zeros(4, 4);
        xb.set(2, 2, 1);
        xb.pack();
        assert_eq!(xb.format(), StorageFormat::Compressed);
    }

    /// The one [`chosen_format`] definition places every density band —
    /// boundaries inclusive on the sparse side.
    #[test]
    fn format_band_thresholds() {
        let cells = 128 * 128;
        let at = |d: f64| (d * cells as f64).round() as usize;
        assert_eq!(chosen_format(0, 128, 128), StorageFormat::Compressed);
        assert_eq!(chosen_format(at(0.25), 128, 128), StorageFormat::Compressed);
        assert_eq!(
            chosen_format(at(0.25) + 1, 128, 128),
            StorageFormat::BitPlanes
        );
        assert_eq!(chosen_format(at(0.40), 128, 128), StorageFormat::BitPlanes);
        assert_eq!(chosen_format(at(0.60), 128, 128), StorageFormat::BitPlanes);
        assert_eq!(chosen_format(at(0.60) + 1, 128, 128), StorageFormat::Dense);
        assert_eq!(chosen_format(cells, 128, 128), StorageFormat::Dense);
        // small / degenerate geometries use the same bands
        assert_eq!(chosen_format(1, 4, 4), StorageFormat::Compressed);
        assert_eq!(chosen_format(8, 4, 4), StorageFormat::BitPlanes);
        assert_eq!(chosen_format(16, 4, 4), StorageFormat::Dense);
    }

    #[test]
    fn storage_bytes_shrink_for_sparse_tiles() {
        let mut xb = Crossbar::zeros(128, 128);
        for i in 0..100 {
            xb.set(i, i, 1 + (i % 3) as u8);
        }
        let dense_bytes = xb.storage_bytes();
        assert_eq!(dense_bytes, 128 * 128);
        let comp = xb.in_format(StorageFormat::Compressed);
        assert!(
            comp.storage_bytes() < dense_bytes / 4,
            "{} bytes compressed vs {dense_bytes} dense",
            comp.storage_bytes()
        );
        // bit-planes: 32 bytes per column + the index, density-independent
        let bp = xb.in_format(StorageFormat::BitPlanes);
        assert_eq!(bp.storage_bytes(), 2 * 128 * 16 + 100 * 2);
        assert!(bp.storage_bytes() < dense_bytes / 2);
    }

    #[test]
    #[should_panic]
    fn from_cells_rejects_duplicates() {
        let _ = Crossbar::from_cells(4, 4, vec![(1, 1, 2), (1, 1, 3)]);
    }

    /// Property: the cached active-wordline/column indexes track `set`
    /// mutations (insert / overwrite / clear) exactly, in every layout,
    /// against a brute-force recount.
    #[test]
    fn active_indexes_track_mutation() {
        check(25, |rng| {
            let rows = 1 + rng.below(XBAR_ROWS);
            let cols = 1 + rng.below(XBAR_COLS);
            let mut dense = Crossbar::zeros(rows, cols);
            let mut comp = Crossbar::zeros(rows, cols).in_format(StorageFormat::Compressed);
            let mut bp = Crossbar::zeros(rows, cols).in_format(StorageFormat::BitPlanes);
            for _ in 0..150 {
                let (r, c) = (rng.below(rows), rng.below(cols));
                let v = rng.below(4) as u8; // 0 = clear
                dense.set(r, c, v);
                comp.set(r, c, v);
                bp.set(r, c, v);
            }
            let live_rows = (0..rows)
                .filter(|&r| (0..cols).any(|c| dense.get(r, c) != 0))
                .count();
            let live_cols = (0..cols)
                .filter(|&c| (0..rows).any(|r| dense.get(r, c) != 0))
                .count();
            for xb in [&dense, &comp, &bp] {
                ensure(xb.active_wordlines() == live_rows, "active wordlines")?;
                ensure(xb.active_columns() == live_cols, "active columns")?;
            }
            // each cached index itself is sorted and complete
            for xb in [&comp, &bp] {
                let idx = xb.active_cols().expect("indexed tiles carry the index");
                ensure(idx.windows(2).all(|w| w[0] < w[1]), "index ascending")?;
                ensure(idx.len() == live_cols, "index length")?;
            }
            Ok(())
        });
    }

    /// `bitline_currents_active` (and its wave twin) only touches active
    /// columns in the indexed layouts: active slots equal the full
    /// variant's, inactive slots keep whatever garbage the buffer held —
    /// and the returned index names exactly the meaningful slots.
    #[test]
    fn active_current_scan_matches_full_scan_on_active_columns() {
        check(25, |rng| {
            let rows = 1 + rng.below(XBAR_ROWS);
            let cols = 1 + rng.below(XBAR_COLS);
            let mut xb = Crossbar::zeros(rows, cols);
            for _ in 0..rng.below(1 + rows * cols / 8) {
                xb.set(rng.below(rows), rng.below(cols), 1 + rng.below(3) as u8);
            }
            let bits: Vec<u8> = (0..rows).map(|_| rng.below(2) as u8).collect();
            let wave = pack_wave(&bits);
            let mut full = vec![0u32; cols];
            xb.bitline_currents(&bits, &mut full);
            for fmt in [StorageFormat::Compressed, StorageFormat::BitPlanes] {
                let indexed = xb.in_format(fmt);
                let mut sparse = vec![0xDEADu32; cols];
                let idx = indexed
                    .bitline_currents_active(&bits, &mut sparse)
                    .expect("indexed layout returns the index")
                    .to_vec();
                let mut waved = vec![0xBEEFu32; cols];
                let widx = indexed
                    .bitline_currents_wave(&wave, &mut waved)
                    .expect("indexed layout returns the index")
                    .to_vec();
                ensure(idx == widx, "byte and wave variants agree on the index")?;
                let active: std::collections::BTreeSet<usize> =
                    idx.iter().map(|&c| c as usize).collect();
                for c in 0..cols {
                    if active.contains(&c) {
                        ensure(sparse[c] == full[c], format!("{fmt:?} active column {c}"))?;
                        ensure(waved[c] == full[c], format!("{fmt:?} wave column {c}"))?;
                    } else {
                        ensure(sparse[c] == 0xDEAD, format!("inactive column {c} written"))?;
                        ensure(waved[c] == 0xBEEF, format!("inactive column {c} waved"))?;
                        ensure(full[c] == 0, "inactive column carries current")?;
                    }
                }
            }
            // dense layout: no index, every slot written, same currents
            let mut d = vec![0xDEADu32; cols];
            ensure(xb.bitline_currents_active(&bits, &mut d).is_none(), "dense index")?;
            ensure(d == full, "dense active variant == full scan")?;
            let mut dw = vec![0xDEADu32; cols];
            ensure(xb.bitline_currents_wave(&wave, &mut dw).is_none(), "dense wave index")?;
            ensure(dw == full, "dense wave variant == full scan")?;
            Ok(())
        });
    }

    /// The `[u64; 2]` word seam sits at row 64: exercise tiles whose row
    /// count straddles it so a packing off-by-one can't hide in the
    /// random-geometry properties.
    #[test]
    fn wave_scan_agrees_across_word_boundaries() {
        for rows in [1, 63, 64, 65, 127, 128] {
            let cols = 8;
            let mut xb = Crossbar::zeros(rows, cols);
            // program the boundary rows and a spread of columns
            for (i, r) in [0, rows.saturating_sub(1), rows / 2].into_iter().enumerate() {
                for c in 0..cols {
                    xb.set(r, c, 1 + ((r + c + i) % 3) as u8);
                }
            }
            // drive only the last row: the highest packed bit
            let mut bits = vec![0u8; rows];
            bits[rows - 1] = 1;
            let wave = pack_wave(&bits);
            let mut want = vec![0u32; cols];
            xb.bitline_currents(&bits, &mut want);
            for fmt in ALL_FORMATS {
                let mut got = vec![0u32; cols];
                xb.in_format(fmt).bitline_currents_wave(&wave, &mut got);
                assert_eq!(got, want, "{fmt:?} at {rows} rows");
            }
        }
    }

    #[test]
    #[should_panic]
    fn wave_beyond_rows_panics() {
        let xb = Crossbar::zeros(64, 4).in_format(StorageFormat::BitPlanes);
        let mut out = vec![0u32; 4];
        // bit 64 names wordline 64 of a 64-row tile — out of range
        xb.bitline_currents_wave(&[0, 1], &mut out);
    }

    #[test]
    fn active_counts_on_edge_tiles() {
        // all-zero tile: nothing active in any layout
        let z = Crossbar::zeros(5, 7);
        assert_eq!(z.active_wordlines(), 0);
        assert_eq!(z.active_columns(), 0);
        for fmt in [StorageFormat::Compressed, StorageFormat::BitPlanes] {
            assert_eq!(z.in_format(fmt).active_cols().unwrap().len(), 0);
        }

        // fully-dense tile: everything active
        let mut full = Crossbar::zeros(3, 4);
        for r in 0..3 {
            for c in 0..4 {
                full.set(r, c, CELL_MAX);
            }
        }
        assert_eq!(full.active_wordlines(), 3);
        assert_eq!(full.active_columns(), 4);
        for fmt in [StorageFormat::Compressed, StorageFormat::BitPlanes] {
            assert_eq!(full.in_format(fmt).active_cols().unwrap(), &[0, 1, 2, 3]);
        }

        // clearing a column's last cell drops it from the index
        for fmt in [StorageFormat::Compressed, StorageFormat::BitPlanes] {
            let mut xb = Crossbar::from_cells(4, 4, vec![(0, 2, 1), (3, 2, 2), (1, 0, 3)])
                .in_format(fmt);
            assert_eq!(xb.active_cols().unwrap(), &[0, 2]);
            xb.set(0, 2, 0);
            assert_eq!(xb.active_cols().unwrap(), &[0, 2], "row 3 still holds col 2");
            xb.set(3, 2, 0);
            assert_eq!(xb.active_cols().unwrap(), &[0]);
            assert_eq!(xb.active_columns(), 1);
        }
    }

    /// Property: tiles built and mutated only through the safe mutators
    /// pass `verify_cells` in every layout — the audit's structural
    /// checks never false-positive on legal construction paths.
    #[test]
    fn verify_cells_clean_on_safe_mutation() {
        check(25, |rng| {
            let rows = 1 + rng.below(XBAR_ROWS);
            let cols = 1 + rng.below(XBAR_COLS);
            let mut xb = Crossbar::zeros(rows, cols);
            for _ in 0..rng.below(1 + rows * cols / 4) {
                xb.set(rng.below(rows), rng.below(cols), rng.below(4) as u8);
            }
            for fmt in ALL_FORMATS {
                let faults = xb.in_format(fmt).verify_cells();
                ensure(faults.is_empty(), format!("{fmt:?}: {faults:?}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn verify_cells_reports_planted_faults() {
        let mut xb = Crossbar::zeros(8, 8);
        for i in 0..6 {
            xb.set(i, i, 1 + (i % 3) as u8);
        }

        // dense: raw out-of-range value (also desyncs nothing else)
        let mut dense = xb.in_format(StorageFormat::Dense);
        dense.corrupt_dense_value(7, 7, CELL_MAX + 2);
        let faults = dense.verify_cells();
        assert!(
            faults.iter().any(|f| matches!(
                f,
                TileFault::ValueOutOfRange { row: 7, col: 7, value } if *value == CELL_MAX + 2
            )),
            "{faults:?}"
        );
        // the raw write also left the census stale (the cell went 0 -> 5)
        assert!(faults
            .iter()
            .any(|f| matches!(f, TileFault::CensusMismatch { .. })));

        // census desync fires in every layout
        for fmt in ALL_FORMATS {
            let mut t = xb.in_format(fmt);
            t.corrupt_census(1);
            assert!(
                t.verify_cells()
                    .iter()
                    .any(|f| matches!(f, TileFault::CensusMismatch { cached, actual }
                        if *cached == 7 && *actual == 6)),
                "{fmt:?}"
            );
        }

        // compressed: entry column rewritten out of order
        let mut comp = xb.in_format(StorageFormat::Compressed);
        comp.corrupt_entry_col(0, 5);
        assert!(comp
            .verify_cells()
            .iter()
            .any(|f| matches!(f, TileFault::IndexInconsistent(_))));

        // compressed: dropped active column
        let mut comp2 = xb.in_format(StorageFormat::Compressed);
        comp2.corrupt_drop_active_col();
        assert!(comp2
            .verify_cells()
            .iter()
            .any(|f| matches!(f, TileFault::IndexInconsistent(_))));

        // bit-planes: a flipped mask bit desyncs census or the index
        let mut bp = xb.in_format(StorageFormat::BitPlanes);
        bp.corrupt_flip_plane_bit(7, 7);
        assert!(bp
            .verify_cells()
            .iter()
            .any(|f| matches!(
                f,
                TileFault::CensusMismatch { .. } | TileFault::PlaneMaskInconsistent(_)
            )));

        // bit-planes: stray padding bit beyond the tile's rows
        let mut pad = Crossbar::zeros(5, 4).in_format(StorageFormat::BitPlanes);
        pad.set(1, 1, 2);
        pad.corrupt_flip_plane_bit(6, 1); // row 6 of a 5-row tile
        assert!(pad
            .verify_cells()
            .iter()
            .any(|f| matches!(f, TileFault::PlaneMaskInconsistent(_))));
    }
}

//! The crossbar array model.
//!
//! One [`Crossbar`] is a 128x128 array of 2-bit ReRAM cells storing one
//! bit-slice of one sign (positive or negative weights map to separate
//! arrays — state-of-the-art accelerators keep them on differential column
//! pairs [10]). Wordlines are driven bit-serially by 1-bit DACs; the
//! bitline current is the dot product of the input bit vector with the
//! column's conductances, in units of one minimum-conductance cell (the
//! ADC's LSB).
//!
//! # Storage formats
//!
//! Bit-slice L1 training drives each 2-bit slice toward ~90%+ zeros, so a
//! tile's cells live behind a polymorphic `CellArray`:
//!
//! * **Dense** — the row-major `Vec<u8>` layout; right for tiles where
//!   most cells are programmed (sequential scan, one byte per cell).
//! * **Compressed** — per-row packed `(col, val)` pairs (CSR-style
//!   `row_ptr` offsets) plus a nonzero-wordline index, so
//!   [`Crossbar::bitline_currents`] touches only programmed cells on
//!   active wordlines, and a nonzero-**column** index
//!   ([`Crossbar::active_cols`]) so the per-tile ADC/recombination loop
//!   ([`Crossbar::bitline_currents_active`]) skips structurally-zero
//!   output columns outright — the remaining O(cols) term at extreme
//!   sparsity.
//!
//! The representation is chosen per tile from its measured density (see
//! [`COMPRESS_MAX_DENSITY`] and [`chosen_format`]); the mapper builds
//! compressed tiles directly without a dense intermediate. The
//! programmed-cell census is cached in the tile (maintained by
//! [`Crossbar::set`], established at build time), so
//! [`Crossbar::nonzero_cells`] is O(1) — the energy roll-up, the planner's
//! scoring loop and the reports stop recounting `rows * cols` cells.

/// ISAAC-style array geometry.
pub const XBAR_ROWS: usize = 128;
pub const XBAR_COLS: usize = 128;

/// Max cell conductance value for 2-bit cells.
pub const CELL_MAX: u8 = 3;

/// Densest tile (programmed cells / total cells) still stored compressed.
///
/// Measured crossover: one compressed entry costs exactly 3 bytes (the
/// `(col, val)` pair lives as parallel `u16`/`u8` arrays — a tuple would
/// pad to 4) and one scattered add, versus the dense row's one byte and
/// one sequential add per cell, so memory parity sits at 1/3 density and
/// the sparse scan wins comfortably below it. A quarter leaves margin for
/// the scatter penalty and the `row_ptr` overhead; Bl1-level slices
/// (>= 85% zeros, i.e. <= 15% density) sit far below it, while
/// dense-random slices (~37% per sign grid) stay dense.
pub const COMPRESS_MAX_DENSITY: f64 = 0.25;

/// How a tile's cells are laid out in memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorageFormat {
    /// row-major `Vec<u8>`, one byte per cell
    Dense,
    /// per-row packed `(col, val)` pairs + nonzero-wordline index
    Compressed,
}

/// The format [`Crossbar::pack`] and the mapper choose for a tile with
/// `nonzero` of `rows * cols` cells programmed — the one density-threshold
/// definition every call site shares.
pub fn chosen_format(nonzero: usize, rows: usize, cols: usize) -> StorageFormat {
    let cells = (rows * cols).max(1);
    if nonzero as f64 / cells as f64 <= COMPRESS_MAX_DENSITY {
        StorageFormat::Compressed
    } else {
        StorageFormat::Dense
    }
}

/// Physical cell storage of one tile — see the module docs for when each
/// representation wins.
#[derive(Debug, Clone)]
enum CellArray {
    /// row-major `rows x cols`, values 0..=3
    Dense(Vec<u8>),
    Compressed {
        /// entry range of row `r` is `row_ptr[r]..row_ptr[r + 1]`
        row_ptr: Vec<u32>,
        /// `(column, value)` pairs as parallel arrays (3 bytes per entry,
        /// no tuple padding), column-ascending within each row
        entry_cols: Vec<u16>,
        entry_vals: Vec<u8>,
        /// rows holding >= 1 programmed cell, ascending — the
        /// nonzero-wordline index the sparse current scan walks
        active_rows: Vec<u16>,
        /// columns holding >= 1 programmed cell, ascending — the
        /// nonzero-column index the per-tile ADC loop walks; a column
        /// outside it can never carry current, so its conversion is
        /// skipped outright
        active_cols: Vec<u16>,
    },
}

/// Assemble the CSR arrays from row-major `(row, col, val)` triples (row
/// ascending, column ascending within a row, `row < rows`, `col < cols`,
/// `val != 0`) — the one compressed-layout builder
/// [`Crossbar::from_cells`] and [`Crossbar::convert`] share, so the
/// representation's invariants live in a single place.
fn build_compressed(
    rows: usize,
    cols: usize,
    cells: impl Iterator<Item = (usize, u16, u8)>,
) -> CellArray {
    let hint = cells.size_hint().0;
    let mut row_ptr = vec![0u32; rows + 1];
    let mut entry_cols = Vec::with_capacity(hint);
    let mut entry_vals = Vec::with_capacity(hint);
    let mut col_seen = vec![false; cols];
    for (r, c, v) in cells {
        row_ptr[r + 1] += 1;
        col_seen[c as usize] = true;
        entry_cols.push(c);
        entry_vals.push(v);
    }
    for r in 0..rows {
        row_ptr[r + 1] += row_ptr[r];
    }
    let active_rows = (0..rows)
        .filter(|&r| row_ptr[r + 1] > row_ptr[r])
        .map(|r| r as u16)
        .collect();
    let active_cols = (0..cols)
        .filter(|&c| col_seen[c])
        .map(|c| c as u16)
        .collect();
    CellArray::Compressed {
        row_ptr,
        entry_cols,
        entry_vals,
        active_rows,
        active_cols,
    }
}

/// A single crossbar array holding 2-bit cells.
#[derive(Debug, Clone)]
pub struct Crossbar {
    store: CellArray,
    rows: usize,
    cols: usize,
    /// programmed-cell census, maintained incrementally — never recounted
    nonzero: usize,
}

impl Crossbar {
    /// An all-zero tile in dense layout (the mutable starting point;
    /// [`Crossbar::pack`] re-chooses the format once programming is done).
    pub fn zeros(rows: usize, cols: usize) -> Self {
        assert!(rows <= XBAR_ROWS && cols <= XBAR_COLS, "{rows}x{cols}");
        Crossbar {
            store: CellArray::Dense(vec![0; rows * cols]),
            rows,
            cols,
            nonzero: 0,
        }
    }

    /// Build a tile from its programmed cells `(row, col, val)` — the
    /// mapper's path. The format is chosen up front from the cell count
    /// ([`chosen_format`]), so sparse tiles go straight to compressed
    /// storage with **no dense intermediate**. Cells may arrive in any
    /// order; values must be non-zero and positions unique.
    pub fn from_cells(rows: usize, cols: usize, mut cells: Vec<(u16, u16, u8)>) -> Self {
        assert!(rows <= XBAR_ROWS && cols <= XBAR_COLS, "{rows}x{cols}");
        cells.sort_unstable();
        for pair in cells.windows(2) {
            assert!(
                (pair[0].0, pair[0].1) != (pair[1].0, pair[1].1),
                "duplicate cell ({}, {})",
                pair[0].0,
                pair[0].1
            );
        }
        let nonzero = cells.len();
        let store = match chosen_format(nonzero, rows, cols) {
            StorageFormat::Dense => {
                let mut data = vec![0u8; rows * cols];
                for &(r, c, v) in &cells {
                    Self::check_cell(rows, cols, r as usize, c as usize, v);
                    data[r as usize * cols + c as usize] = v;
                }
                CellArray::Dense(data)
            }
            StorageFormat::Compressed => {
                for &(r, c, v) in &cells {
                    Self::check_cell(rows, cols, r as usize, c as usize, v);
                }
                build_compressed(rows, cols, cells.iter().map(|&(r, c, v)| (r as usize, c, v)))
            }
        };
        Crossbar {
            store,
            rows,
            cols,
            nonzero,
        }
    }

    fn check_cell(rows: usize, cols: usize, r: usize, c: usize, v: u8) {
        assert!(r < rows && c < cols, "cell ({r},{c}) outside {rows}x{cols}");
        assert!((1..=CELL_MAX).contains(&v), "cell value {v}");
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The current storage layout.
    pub fn format(&self) -> StorageFormat {
        match self.store {
            CellArray::Dense(_) => StorageFormat::Dense,
            CellArray::Compressed { .. } => StorageFormat::Compressed,
        }
    }

    /// Programmed fraction of the tile's cells.
    pub fn density(&self) -> f64 {
        let cells = self.rows * self.cols;
        if cells == 0 {
            0.0
        } else {
            self.nonzero as f64 / cells as f64
        }
    }

    /// Heap bytes the cell storage occupies under the current format.
    pub fn storage_bytes(&self) -> usize {
        match &self.store {
            CellArray::Dense(cells) => cells.len(),
            CellArray::Compressed {
                row_ptr,
                entry_cols,
                entry_vals,
                active_rows,
                active_cols,
            } => {
                entry_cols.len() * std::mem::size_of::<u16>()
                    + entry_vals.len()
                    + row_ptr.len() * std::mem::size_of::<u32>()
                    + active_rows.len() * std::mem::size_of::<u16>()
                    + active_cols.len() * std::mem::size_of::<u16>()
            }
        }
    }

    /// Program one cell, maintaining the cached census. Works in either
    /// representation — compressed updates splice the entry list, which is
    /// fine off the hot path (programming happens once, at map time).
    pub fn set(&mut self, r: usize, c: usize, v: u8) {
        assert!(v <= CELL_MAX, "cell value {v}");
        assert!(
            r < self.rows && c < self.cols,
            "cell ({r},{c}) outside {}x{}",
            self.rows,
            self.cols
        );
        match &mut self.store {
            CellArray::Dense(cells) => {
                let cell = &mut cells[r * self.cols + c];
                self.nonzero += (v != 0) as usize;
                self.nonzero -= (*cell != 0) as usize;
                *cell = v;
            }
            CellArray::Compressed {
                row_ptr,
                entry_cols,
                entry_vals,
                active_rows,
                active_cols,
            } => {
                let lo = row_ptr[r] as usize;
                let hi = row_ptr[r + 1] as usize;
                match entry_cols[lo..hi].binary_search(&(c as u16)) {
                    Ok(i) if v != 0 => entry_vals[lo + i] = v,
                    Ok(i) => {
                        // clearing the row's only entry deactivates it
                        entry_cols.remove(lo + i);
                        entry_vals.remove(lo + i);
                        for p in row_ptr[r + 1..].iter_mut() {
                            *p -= 1;
                        }
                        if hi - lo == 1 {
                            if let Ok(a) = active_rows.binary_search(&(r as u16)) {
                                active_rows.remove(a);
                            }
                        }
                        // deactivate the column once no other row holds it
                        // (the membership scan is O(entries) — fine off
                        // the hot path; programming happens at map time)
                        if !entry_cols.contains(&(c as u16)) {
                            if let Ok(a) = active_cols.binary_search(&(c as u16)) {
                                active_cols.remove(a);
                            }
                        }
                        self.nonzero -= 1;
                    }
                    Err(_) if v == 0 => {}
                    Err(i) => {
                        entry_cols.insert(lo + i, c as u16);
                        entry_vals.insert(lo + i, v);
                        for p in row_ptr[r + 1..].iter_mut() {
                            *p += 1;
                        }
                        if hi == lo {
                            if let Err(a) = active_rows.binary_search(&(r as u16)) {
                                active_rows.insert(a, r as u16);
                            }
                        }
                        if let Err(a) = active_cols.binary_search(&(c as u16)) {
                            active_cols.insert(a, c as u16);
                        }
                        self.nonzero += 1;
                    }
                }
            }
        }
    }

    pub fn get(&self, r: usize, c: usize) -> u8 {
        assert!(
            r < self.rows && c < self.cols,
            "cell ({r},{c}) outside {}x{}",
            self.rows,
            self.cols
        );
        match &self.store {
            CellArray::Dense(cells) => cells[r * self.cols + c],
            CellArray::Compressed {
                row_ptr,
                entry_cols,
                entry_vals,
                ..
            } => {
                let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
                match entry_cols[lo..hi].binary_search(&(c as u16)) {
                    Ok(i) => entry_vals[lo + i],
                    Err(_) => 0,
                }
            }
        }
    }

    /// Number of programmed (non-zero) cells — the mapped-sparsity census,
    /// cached at program time (O(1), never a recount).
    pub fn nonzero_cells(&self) -> usize {
        self.nonzero
    }

    /// Re-lay the cells out in `fmt` (no-op when already there).
    pub fn convert(&mut self, fmt: StorageFormat) {
        if self.format() == fmt {
            return;
        }
        match fmt {
            StorageFormat::Dense => {
                let mut data = vec![0u8; self.rows * self.cols];
                if let CellArray::Compressed {
                    row_ptr,
                    entry_cols,
                    entry_vals,
                    ..
                } = &self.store
                {
                    for r in 0..self.rows {
                        for i in row_ptr[r] as usize..row_ptr[r + 1] as usize {
                            data[r * self.cols + entry_cols[i] as usize] = entry_vals[i];
                        }
                    }
                }
                self.store = CellArray::Dense(data);
            }
            StorageFormat::Compressed => {
                let (rows, cols) = (self.rows, self.cols);
                let CellArray::Dense(cells) = &self.store else {
                    return;
                };
                let mut triples = Vec::with_capacity(self.nonzero);
                for r in 0..rows {
                    for c in 0..cols {
                        let v = cells[r * cols + c];
                        if v != 0 {
                            triples.push((r, c as u16, v));
                        }
                    }
                }
                let packed = build_compressed(rows, cols, triples.into_iter());
                self.store = packed;
            }
        }
    }

    /// A clone laid out in `fmt` — the benches' and the representation
    /// property tests' handle for comparing both paths on identical cells.
    pub fn in_format(&self, fmt: StorageFormat) -> Crossbar {
        let mut xb = self.clone();
        xb.convert(fmt);
        xb
    }

    /// Choose the storage format from the measured density (see
    /// [`COMPRESS_MAX_DENSITY`]) — call once programming is complete.
    pub fn pack(&mut self) {
        self.convert(chosen_format(self.nonzero, self.rows, self.cols));
    }

    /// Per-column sum of conductances: the worst-case bitline current
    /// (every wordline driving a '1'), in LSB units.
    pub fn column_conductance_sums(&self) -> Vec<u32> {
        let mut sums = vec![0u32; self.cols];
        match &self.store {
            CellArray::Dense(cells) => {
                for r in 0..self.rows {
                    let row = &cells[r * self.cols..(r + 1) * self.cols];
                    for (s, &v) in sums.iter_mut().zip(row) {
                        *s += v as u32;
                    }
                }
            }
            CellArray::Compressed {
                entry_cols,
                entry_vals,
                ..
            } => {
                for (&c, &v) in entry_cols.iter().zip(entry_vals) {
                    sums[c as usize] += v as u32;
                }
            }
        }
        sums
    }

    /// Wordlines holding >= 1 programmed cell — the rows the sparse
    /// current scan visits. O(1) in the compressed layout (the cached
    /// nonzero-wordline index); a recount in the dense layout (stats
    /// paths only, never the hot loop).
    pub fn active_wordlines(&self) -> usize {
        match &self.store {
            CellArray::Dense(cells) => (0..self.rows)
                .filter(|&r| cells[r * self.cols..(r + 1) * self.cols].iter().any(|&v| v != 0))
                .count(),
            CellArray::Compressed { active_rows, .. } => active_rows.len(),
        }
    }

    /// Output columns holding >= 1 programmed cell — the columns whose
    /// ADC actually converts (structurally-zero columns are skipped, see
    /// [`Self::bitline_currents_active`]). O(1) in the compressed layout;
    /// a recount in the dense layout (stats paths only).
    pub fn active_columns(&self) -> usize {
        match &self.store {
            CellArray::Dense(cells) => {
                let mut seen = vec![false; self.cols];
                for r in 0..self.rows {
                    let row = &cells[r * self.cols..(r + 1) * self.cols];
                    for (s, &v) in seen.iter_mut().zip(row) {
                        *s |= v != 0;
                    }
                }
                seen.iter().filter(|&&s| s).count()
            }
            CellArray::Compressed { active_cols, .. } => active_cols.len(),
        }
    }

    /// The nonzero-column index (ascending), when the layout caches one:
    /// `Some` for compressed tiles, `None` for dense ones. A column
    /// outside the index holds no programmed cell and can never carry
    /// current.
    pub fn active_cols(&self) -> Option<&[u16]> {
        match &self.store {
            CellArray::Dense(_) => None,
            CellArray::Compressed { active_cols, .. } => Some(active_cols),
        }
    }

    /// Columns whose ADC actually converts under this layout — what the
    /// energy model bills and the resolution census counts. Compressed
    /// tiles convert only their nonzero-column index; dense tiles carry
    /// no index, so every column converts (matching the dense branch of
    /// the simulator's ADC loop exactly). O(1) in both layouts.
    pub fn converting_columns(&self) -> usize {
        match &self.store {
            CellArray::Dense(_) => self.cols,
            CellArray::Compressed { active_cols, .. } => active_cols.len(),
        }
    }

    /// Accumulate one bit-plane's currents into `out` (no zeroing — the
    /// callers own the reset policy).
    fn accumulate_currents(&self, bits: &[u8], out: &mut [u32]) {
        match &self.store {
            CellArray::Dense(cells) => {
                for (r, &b) in bits.iter().enumerate() {
                    if b == 0 {
                        continue;
                    }
                    let row = &cells[r * self.cols..(r + 1) * self.cols];
                    for (o, &v) in out.iter_mut().zip(row) {
                        *o += v as u32;
                    }
                }
            }
            CellArray::Compressed {
                row_ptr,
                entry_cols,
                entry_vals,
                active_rows,
                ..
            } => {
                // touch only programmed cells on active wordlines
                for &r in active_rows {
                    let r = r as usize;
                    if bits[r] == 0 {
                        continue;
                    }
                    let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
                    for (&c, &v) in entry_cols[lo..hi].iter().zip(&entry_vals[lo..hi]) {
                        out[c as usize] += v as u32;
                    }
                }
            }
        }
    }

    /// Bitline currents for one input bit-plane (`bits[r]` in {0,1}).
    /// Every slot of `out` is written (zeroed, then accumulated).
    ///
    /// The buffer lengths are hard asserts in **both** representations and
    /// all build profiles: a short `out` would silently truncate the `zip`
    /// accumulation in release builds if only debug-asserted, and a short
    /// `bits` would drop wordlines.
    pub fn bitline_currents(&self, bits: &[u8], out: &mut [u32]) {
        assert_eq!(bits.len(), self.rows, "input bit-plane length");
        assert_eq!(out.len(), self.cols, "bitline current buffer length");
        out.fill(0);
        self.accumulate_currents(bits, out);
    }

    /// Sparse variant of [`Self::bitline_currents`] for the per-tile ADC
    /// loop: in the compressed layout, only **active** columns of `out`
    /// are zeroed and accumulated — slots of structurally-zero columns
    /// are neither written nor meaningful afterwards — and the cached
    /// nonzero-column index is returned so the caller converts exactly
    /// those columns. In the dense layout this is `bitline_currents`
    /// (every slot valid) and the index is `None`. Same hard length
    /// asserts as the full variant.
    pub fn bitline_currents_active(&self, bits: &[u8], out: &mut [u32]) -> Option<&[u16]> {
        assert_eq!(bits.len(), self.rows, "input bit-plane length");
        assert_eq!(out.len(), self.cols, "bitline current buffer length");
        if let CellArray::Compressed { active_cols, .. } = &self.store {
            for &c in active_cols {
                out[c as usize] = 0;
            }
            self.accumulate_currents(bits, out);
            Some(active_cols)
        } else {
            out.fill(0);
            self.accumulate_currents(bits, out);
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, ensure};

    #[test]
    fn geometry_limits_enforced() {
        let xb = Crossbar::zeros(128, 128);
        assert_eq!((xb.rows(), xb.cols()), (128, 128));
        assert_eq!(xb.format(), StorageFormat::Dense);
    }

    #[test]
    #[should_panic]
    fn oversized_array_panics() {
        let _ = Crossbar::zeros(129, 10);
    }

    #[test]
    #[should_panic]
    fn cell_value_range_enforced() {
        let mut xb = Crossbar::zeros(2, 2);
        xb.set(0, 0, 4);
    }

    #[test]
    #[should_panic]
    fn short_current_buffer_panics_in_every_profile() {
        // a short `out` used to truncate silently in release builds
        let xb = Crossbar::zeros(4, 4);
        let mut out = vec![0u32; 3];
        xb.bitline_currents(&[1, 1, 1, 1], &mut out);
    }

    #[test]
    #[should_panic]
    fn short_bit_plane_panics() {
        let xb = Crossbar::zeros(4, 4);
        let mut out = vec![0u32; 4];
        xb.bitline_currents(&[1, 1, 1], &mut out);
    }

    #[test]
    fn column_sums_and_currents_agree_for_all_ones_input() {
        check(25, |rng| {
            let rows = 1 + rng.below(128);
            let cols = 1 + rng.below(128);
            let mut xb = Crossbar::zeros(rows, cols);
            for r in 0..rows {
                for c in 0..cols {
                    xb.set(r, c, rng.below(4) as u8);
                }
            }
            let bits = vec![1u8; rows];
            let mut cur = vec![0u32; cols];
            xb.bitline_currents(&bits, &mut cur);
            ensure(
                cur == xb.column_conductance_sums(),
                "all-ones currents == column sums",
            )?;
            Ok(())
        });
    }

    #[test]
    fn currents_respect_input_bits() {
        let mut xb = Crossbar::zeros(3, 2);
        xb.set(0, 0, 3);
        xb.set(1, 0, 2);
        xb.set(2, 1, 1);
        let mut cur = vec![0u32; 2];
        xb.bitline_currents(&[1, 0, 1], &mut cur);
        assert_eq!(cur, vec![3, 1]);
        // identical answers from the compressed layout
        let comp = xb.in_format(StorageFormat::Compressed);
        comp.bitline_currents(&[1, 0, 1], &mut cur);
        assert_eq!(cur, vec![3, 1]);
    }

    #[test]
    fn nonzero_cell_census() {
        let mut xb = Crossbar::zeros(4, 4);
        assert_eq!(xb.nonzero_cells(), 0);
        xb.set(1, 2, 2);
        xb.set(3, 3, 1);
        assert_eq!(xb.nonzero_cells(), 2);
        // the cache tracks overwrites and clears, not just first writes
        xb.set(1, 2, 3);
        assert_eq!(xb.nonzero_cells(), 2);
        xb.set(3, 3, 0);
        assert_eq!(xb.nonzero_cells(), 1);
        xb.set(3, 3, 0);
        assert_eq!(xb.nonzero_cells(), 1);
    }

    /// Property: Dense and Compressed agree bit-exactly on every read path
    /// across random densities and partial-tile geometries.
    #[test]
    fn representations_agree_bit_exactly() {
        check(40, |rng| {
            let rows = 1 + rng.below(XBAR_ROWS);
            let cols = 1 + rng.below(XBAR_COLS);
            // fill in 0..=100 percent: hits near-empty and near-full tiles
            let fill = rng.below(101);
            let mut dense = Crossbar::zeros(rows, cols);
            for r in 0..rows {
                for c in 0..cols {
                    if rng.below(100) < fill {
                        dense.set(r, c, 1 + rng.below(3) as u8);
                    }
                }
            }
            let comp = dense.in_format(StorageFormat::Compressed);
            ensure(comp.format() == StorageFormat::Compressed, "converted")?;
            ensure(comp.nonzero_cells() == dense.nonzero_cells(), "census")?;
            ensure(
                comp.column_conductance_sums() == dense.column_conductance_sums(),
                "column sums",
            )?;
            let bits: Vec<u8> = (0..rows).map(|_| rng.below(2) as u8).collect();
            let mut a = vec![0u32; cols];
            let mut b = vec![0u32; cols];
            dense.bitline_currents(&bits, &mut a);
            comp.bitline_currents(&bits, &mut b);
            ensure(a == b, "bitline currents")?;
            // round-trip back to dense preserves every cell
            let back = comp.in_format(StorageFormat::Dense);
            for r in 0..rows {
                for c in 0..cols {
                    ensure(back.get(r, c) == dense.get(r, c), "round-trip cell")?;
                }
            }
            Ok(())
        });
    }

    /// Property: `set` on a compressed tile (update / insert / clear)
    /// tracks a dense mirror exactly, census included.
    #[test]
    fn compressed_set_matches_dense_mirror() {
        check(30, |rng| {
            let rows = 1 + rng.below(XBAR_ROWS);
            let cols = 1 + rng.below(XBAR_COLS);
            let mut dense = Crossbar::zeros(rows, cols);
            let mut comp = Crossbar::zeros(rows, cols).in_format(StorageFormat::Compressed);
            for _ in 0..200 {
                let (r, c) = (rng.below(rows), rng.below(cols));
                let v = rng.below(4) as u8; // 0 = clear
                dense.set(r, c, v);
                comp.set(r, c, v);
            }
            ensure(
                comp.nonzero_cells() == dense.nonzero_cells(),
                "census after mutation",
            )?;
            for r in 0..rows {
                for c in 0..cols {
                    ensure(comp.get(r, c) == dense.get(r, c), "cell after mutation")?;
                }
            }
            let bits = vec![1u8; rows];
            let mut a = vec![0u32; cols];
            let mut b = vec![0u32; cols];
            dense.bitline_currents(&bits, &mut a);
            comp.bitline_currents(&bits, &mut b);
            ensure(a == b, "currents after mutation")?;
            Ok(())
        });
    }

    #[test]
    fn format_edges_all_zero_and_fully_dense() {
        // all-zero tile: compressed layout holds no entries, reads zeros
        let z = Crossbar::zeros(5, 7).in_format(StorageFormat::Compressed);
        assert_eq!(z.nonzero_cells(), 0);
        assert_eq!(z.density(), 0.0);
        let mut cur = vec![9u32; 7];
        z.bitline_currents(&[1; 5], &mut cur);
        assert!(cur.iter().all(|&v| v == 0));
        assert_eq!(z.get(4, 6), 0);

        // fully-dense tile survives the compressed detour bit-exactly
        let mut full = Crossbar::zeros(3, 4);
        for r in 0..3 {
            for c in 0..4 {
                full.set(r, c, CELL_MAX);
            }
        }
        let fc = full.in_format(StorageFormat::Compressed);
        assert_eq!(fc.nonzero_cells(), 12);
        assert_eq!(fc.density(), 1.0);
        assert_eq!(fc.column_conductance_sums(), full.column_conductance_sums());
    }

    #[test]
    fn from_cells_picks_format_by_density() {
        // 2 of 16 cells (12.5%) -> compressed, built with no dense pass
        let sparse = Crossbar::from_cells(4, 4, vec![(3, 3, 1), (0, 1, 2)]);
        assert_eq!(sparse.format(), StorageFormat::Compressed);
        assert_eq!(sparse.nonzero_cells(), 2);
        assert_eq!(sparse.get(0, 1), 2);
        assert_eq!(sparse.get(3, 3), 1);
        assert_eq!(sparse.get(1, 1), 0);

        // 8 of 16 cells (50%) -> dense
        let cells: Vec<(u16, u16, u8)> = (0u16..8).map(|i| (i / 4, i % 4, 3u8)).collect();
        let dense = Crossbar::from_cells(4, 4, cells);
        assert_eq!(dense.format(), StorageFormat::Dense);
        assert_eq!(dense.nonzero_cells(), 8);

        // pack() applies the same threshold to an already-built tile
        let mut xb = Crossbar::zeros(4, 4);
        xb.set(2, 2, 1);
        xb.pack();
        assert_eq!(xb.format(), StorageFormat::Compressed);
        assert_eq!(chosen_format(1, 4, 4), StorageFormat::Compressed);
        assert_eq!(chosen_format(8, 4, 4), StorageFormat::Dense);
    }

    #[test]
    fn storage_bytes_shrink_for_sparse_tiles() {
        let mut xb = Crossbar::zeros(128, 128);
        for i in 0..100 {
            xb.set(i, i, 1 + (i % 3) as u8);
        }
        let dense_bytes = xb.storage_bytes();
        assert_eq!(dense_bytes, 128 * 128);
        let comp = xb.in_format(StorageFormat::Compressed);
        assert!(
            comp.storage_bytes() < dense_bytes / 4,
            "{} bytes compressed vs {dense_bytes} dense",
            comp.storage_bytes()
        );
    }

    #[test]
    #[should_panic]
    fn from_cells_rejects_duplicates() {
        let _ = Crossbar::from_cells(4, 4, vec![(1, 1, 2), (1, 1, 3)]);
    }

    /// Property: the cached active-wordline/column indexes track `set`
    /// mutations (insert / overwrite / clear) exactly, in both layouts,
    /// against a brute-force recount.
    #[test]
    fn active_indexes_track_mutation() {
        check(25, |rng| {
            let rows = 1 + rng.below(XBAR_ROWS);
            let cols = 1 + rng.below(XBAR_COLS);
            let mut dense = Crossbar::zeros(rows, cols);
            let mut comp = Crossbar::zeros(rows, cols).in_format(StorageFormat::Compressed);
            for _ in 0..150 {
                let (r, c) = (rng.below(rows), rng.below(cols));
                let v = rng.below(4) as u8; // 0 = clear
                dense.set(r, c, v);
                comp.set(r, c, v);
            }
            let live_rows = (0..rows)
                .filter(|&r| (0..cols).any(|c| dense.get(r, c) != 0))
                .count();
            let live_cols = (0..cols)
                .filter(|&c| (0..rows).any(|r| dense.get(r, c) != 0))
                .count();
            for xb in [&dense, &comp] {
                ensure(xb.active_wordlines() == live_rows, "active wordlines")?;
                ensure(xb.active_columns() == live_cols, "active columns")?;
            }
            // the compressed index itself is sorted and complete
            let idx = comp.active_cols().expect("compressed tiles carry the index");
            ensure(idx.windows(2).all(|w| w[0] < w[1]), "index ascending")?;
            ensure(idx.len() == live_cols, "index length")?;
            Ok(())
        });
    }

    /// `bitline_currents_active` only touches active columns in the
    /// compressed layout: active slots equal the full variant's, inactive
    /// slots keep whatever garbage the buffer held — and the returned
    /// index names exactly the meaningful slots.
    #[test]
    fn active_current_scan_matches_full_scan_on_active_columns() {
        check(25, |rng| {
            let rows = 1 + rng.below(XBAR_ROWS);
            let cols = 1 + rng.below(XBAR_COLS);
            let mut xb = Crossbar::zeros(rows, cols);
            for _ in 0..rng.below(1 + rows * cols / 8) {
                xb.set(rng.below(rows), rng.below(cols), 1 + rng.below(3) as u8);
            }
            let comp = xb.in_format(StorageFormat::Compressed);
            let bits: Vec<u8> = (0..rows).map(|_| rng.below(2) as u8).collect();
            let mut full = vec![0u32; cols];
            comp.bitline_currents(&bits, &mut full);
            let mut sparse = vec![0xDEADu32; cols];
            let idx = comp
                .bitline_currents_active(&bits, &mut sparse)
                .expect("compressed layout returns the index")
                .to_vec();
            let active: std::collections::BTreeSet<usize> =
                idx.iter().map(|&c| c as usize).collect();
            for c in 0..cols {
                if active.contains(&c) {
                    ensure(sparse[c] == full[c], format!("active column {c}"))?;
                } else {
                    ensure(sparse[c] == 0xDEAD, format!("inactive column {c} written"))?;
                    ensure(full[c] == 0, "inactive column carries current")?;
                }
            }
            // dense layout: no index, every slot written, same currents
            let mut d = vec![0xDEADu32; cols];
            ensure(xb.bitline_currents_active(&bits, &mut d).is_none(), "dense index")?;
            ensure(d == full, "dense active variant == full scan")?;
            Ok(())
        });
    }

    #[test]
    fn active_counts_on_edge_tiles() {
        // all-zero tile: nothing active in either layout
        let z = Crossbar::zeros(5, 7);
        assert_eq!(z.active_wordlines(), 0);
        assert_eq!(z.active_columns(), 0);
        let zc = z.in_format(StorageFormat::Compressed);
        assert_eq!(zc.active_cols().unwrap().len(), 0);

        // fully-dense tile: everything active
        let mut full = Crossbar::zeros(3, 4);
        for r in 0..3 {
            for c in 0..4 {
                full.set(r, c, CELL_MAX);
            }
        }
        assert_eq!(full.active_wordlines(), 3);
        assert_eq!(full.active_columns(), 4);
        let fc = full.in_format(StorageFormat::Compressed);
        assert_eq!(fc.active_cols().unwrap(), &[0, 1, 2, 3]);

        // clearing a column's last cell drops it from the index
        let mut xb = Crossbar::from_cells(4, 4, vec![(0, 2, 1), (3, 2, 2), (1, 0, 3)]);
        assert_eq!(xb.format(), StorageFormat::Compressed);
        assert_eq!(xb.active_cols().unwrap(), &[0, 2]);
        xb.set(0, 2, 0);
        assert_eq!(xb.active_cols().unwrap(), &[0, 2], "row 3 still holds col 2");
        xb.set(3, 2, 0);
        assert_eq!(xb.active_cols().unwrap(), &[0]);
        assert_eq!(xb.active_columns(), 1);
    }
}

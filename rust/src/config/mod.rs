//! Run configuration: CLI flags -> a typed [`RunConfig`].
//!
//! Defaults are sized for the sandbox testbed (scaled-down schedules on
//! synthetic data, DESIGN.md §Substitutions); every knob is a flag so the
//! full paper schedules are one command away on real hardware/data.

use std::path::PathBuf;

use anyhow::Result;

use crate::util::cli::Args;

/// The three methods of Tables 1/2, plus an unregularized baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// no regularizer, no pruning (pretraining / ablation reference)
    Baseline,
    /// magnitude pruning + fine-tune (the tables' "Pruned" row)
    Pruned,
    /// element-wise l1 on the quantized weights (the "l1" row)
    L1,
    /// the paper's bit-slice l1 (the "Bl1" row)
    Bl1,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "baseline" => Method::Baseline,
            "pruned" => Method::Pruned,
            "l1" => Method::L1,
            "bl1" => Method::Bl1,
            other => anyhow::bail!("unknown method {other:?} (baseline|pruned|l1|bl1)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Baseline => "baseline",
            Method::Pruned => "pruned",
            Method::L1 => "l1",
            Method::Bl1 => "bl1",
        }
    }
}

/// Everything a training/eval run needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,
    pub dataset: String,
    pub method: Method,
    /// main-phase optimization steps
    pub steps: usize,
    /// pretraining steps (l1 phase for Bl1; unregularized for Pruned)
    pub pretrain_steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub alpha_l1: f32,
    pub alpha_bl1: f32,
    /// fraction of weights zeroed per layer by magnitude pruning
    pub prune_fraction: f32,
    pub seed: u64,
    /// synthetic-dataset sizes (ignored when real data is present)
    pub train_examples: usize,
    pub test_examples: usize,
    /// record a Fig-2 sparsity trace point every N steps (0 = off)
    pub trace_every: usize,
    pub artifacts_dir: PathBuf,
    pub data_dir: PathBuf,
    pub out_dir: PathBuf,
    /// batch-prefetch queue depth
    pub prefetch: usize,
}

impl RunConfig {
    /// Sensible defaults for the given model (paper Sec. 3 workloads).
    pub fn defaults(model: &str) -> RunConfig {
        let dataset = if model == "mlp" { "mnist" } else { "cifar10" };
        RunConfig {
            model: model.to_string(),
            dataset: dataset.to_string(),
            method: Method::Bl1,
            steps: 400,
            pretrain_steps: 200,
            lr: 0.05,
            momentum: 0.9,
            // alphas tuned on the synthetic tasks to land near the paper's
            // accuracy/sparsity trade-off region
            alpha_l1: 1e-5,
            alpha_bl1: 5e-7,
            prune_fraction: 0.90,
            seed: 42,
            train_examples: if model == "mlp" { 8192 } else { 2048 },
            test_examples: if model == "mlp" { 2048 } else { 512 },
            trace_every: 0,
            artifacts_dir: PathBuf::from("artifacts"),
            data_dir: PathBuf::from("data"),
            out_dir: PathBuf::from("runs"),
            prefetch: 4,
        }
    }

    /// Apply CLI overrides on top of the model defaults.
    pub fn from_args(args: &Args) -> Result<RunConfig> {
        let model = args.str_or("model", "mlp");
        let mut c = RunConfig::defaults(&model);
        if let Some(ds) = args.str_opt("dataset") {
            c.dataset = ds;
        }
        if let Some(m) = args.str_opt("method") {
            c.method = Method::parse(&m)?;
        }
        c.steps = args.usize_or("steps", c.steps)?;
        c.pretrain_steps = args.usize_or("pretrain-steps", c.pretrain_steps)?;
        c.lr = args.f32_or("lr", c.lr)?;
        c.momentum = args.f32_or("momentum", c.momentum)?;
        c.alpha_l1 = args.f32_or("alpha-l1", c.alpha_l1)?;
        c.alpha_bl1 = args.f32_or("alpha-bl1", c.alpha_bl1)?;
        c.prune_fraction = args.f32_or("prune-fraction", c.prune_fraction)?;
        c.seed = args.u64_or("seed", c.seed)?;
        c.train_examples = args.usize_or("train-examples", c.train_examples)?;
        c.test_examples = args.usize_or("test-examples", c.test_examples)?;
        c.trace_every = args.usize_or("trace-every", c.trace_every)?;
        c.prefetch = args.usize_or("prefetch", c.prefetch)?;
        c.artifacts_dir = PathBuf::from(args.str_or("artifacts-dir", "artifacts"));
        c.data_dir = PathBuf::from(args.str_or("data-dir", "data"));
        c.out_dir = PathBuf::from(args.str_or("out-dir", "runs"));
        anyhow::ensure!(c.prune_fraction >= 0.0 && c.prune_fraction < 1.0);
        Ok(c)
    }

    /// Run label used for output paths: `<model>-<method>`.
    pub fn label(&self) -> String {
        format!("{}-{}", self.model, self.method.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn defaults_pick_dataset_by_model() {
        assert_eq!(RunConfig::defaults("mlp").dataset, "mnist");
        assert_eq!(RunConfig::defaults("vgg11").dataset, "cifar10");
    }

    #[test]
    fn args_override_defaults() {
        let a = argv("train --model resnet20 --method l1 --steps 7 --lr 0.2 --seed 9");
        let c = RunConfig::from_args(&a).unwrap();
        assert_eq!(c.model, "resnet20");
        assert_eq!(c.method, Method::L1);
        assert_eq!(c.steps, 7);
        assert!((c.lr - 0.2).abs() < 1e-9);
        assert_eq!(c.seed, 9);
        assert_eq!(c.label(), "resnet20-l1");
    }

    #[test]
    fn method_parse_rejects_unknown() {
        assert!(Method::parse("l2").is_err());
        assert_eq!(Method::parse("bl1").unwrap(), Method::Bl1);
    }

    #[test]
    fn prune_fraction_validated() {
        let a = argv("train --prune-fraction 1.5");
        assert!(RunConfig::from_args(&a).is_err());
    }
}

//! Paper-style table and figure emitters.
//!
//! Formats the measured numbers in the same layout as the paper's Tables
//! 1–3 and dumps Figure 2's series as CSV, so EXPERIMENTS.md can show
//! paper-vs-measured side by side.

use crate::quant::N_SLICES;
use crate::reram::device::DeviceConfig;
use crate::reram::energy::AdcSavingRow;
use crate::reram::planner::SearchStats;
use crate::sparsity::SliceStats;
use crate::util::json::{num, obj, s, Json};

/// One row of Table 1/2: a method's accuracy + slice sparsity.
#[derive(Debug, Clone)]
pub struct MethodRow {
    pub method: String,
    pub accuracy: f64,
    pub stats: SliceStats,
}

/// Render Table 1/2 (markdown) for a set of method rows.
pub fn sparsity_table(title: &str, rows: &[MethodRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("### {title}\n\n"));
    out.push_str(
        "| Method | Accuracy | B^3 | B^2 | B^1 | B^0 | Average |\n\
         |--------|----------|-----|-----|-----|-----|---------|\n",
    );
    for r in rows {
        let ratios = r.stats.ratios_msb_first();
        let (mean, std) = r.stats.mean_std();
        out.push_str(&format!(
            "| {} | {:.2}% | {:.2}% | {:.2}% | {:.2}% | {:.2}% | {:.2}±{:.2}% |\n",
            r.method,
            r.accuracy * 100.0,
            ratios[0] * 100.0,
            ratios[1] * 100.0,
            ratios[2] * 100.0,
            ratios[3] * 100.0,
            mean * 100.0,
            std * 100.0,
        ));
    }
    out
}

/// Render Table 3 (markdown).
pub fn adc_table(rows: &[AdcSavingRow]) -> String {
    let mut out = String::new();
    out.push_str(
        "| Group | Baseline | Resolution | Energy Saving | Speedup | Area Saving |\n\
         |-------|----------|------------|---------------|---------|-------------|\n",
    );
    for r in rows {
        let group = if r.group == 3 {
            "XB_3".to_string()
        } else {
            format!("XB_{}", r.group)
        };
        out.push_str(&format!(
            "| {} | {} bit | {} bit | {:.1}x | {:.2}x | {:.0}x |\n",
            group, r.baseline_bits, r.bits, r.energy_saving, r.speedup, r.area_saving
        ));
    }
    out
}

/// Render a Fig-2 style series as CSV text (step + MSB-first ratios).
pub fn fig2_csv(traces: &[(String, Vec<crate::sparsity::TracePoint>)]) -> String {
    let mut out = String::from("method,step,b3,b2,b1,b0\n");
    for (method, points) in traces {
        for p in points {
            out.push_str(&format!(
                "{},{},{:.6},{:.6},{:.6},{:.6}\n",
                method, p.step, p.ratios[0], p.ratios[1], p.ratios[2], p.ratios[3]
            ));
        }
    }
    out
}

/// One measured configuration of the batched serving engine
/// (`serve::ServingStats::row` exports into this).
#[derive(Debug, Clone)]
pub struct ServingRow {
    pub backend: String,
    pub max_batch: usize,
    pub workers: usize,
    pub requests: usize,
    /// requests that completed with an inference error (still counted in
    /// `requests` and the latency distribution)
    pub errors: usize,
    /// mean assembled batch size (dynamic batching efficiency)
    pub mean_batch: f64,
    pub throughput_rps: f64,
    pub latency_mean_ms: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    /// the engine's latency target when it served under an SLO policy
    pub slo_ms: Option<f64>,
    /// requests that finished over the target (0 when `slo_ms` is None)
    pub slo_violations: usize,
}

/// Render the serving-throughput table (markdown). The SLO column shows
/// `violations/requests @ target` for rows served under a policy, `-`
/// otherwise.
pub fn serving_table(rows: &[ServingRow]) -> String {
    let mut out = String::new();
    out.push_str(
        "| Backend | Max batch | Workers | Requests | Errors | Mean batch | req/s | p50 ms | p99 ms | SLO |\n\
         |---------|-----------|---------|----------|--------|------------|-------|--------|--------|-----|\n",
    );
    for r in rows {
        let slo = match r.slo_ms {
            Some(target) => format!("{}/{} @ {target} ms", r.slo_violations, r.requests),
            None => "-".to_string(),
        };
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {:.1} | {:.0} | {:.3} | {:.3} | {} |\n",
            r.backend,
            r.max_batch,
            r.workers,
            r.requests,
            r.errors,
            r.mean_batch,
            r.throughput_rps,
            r.latency_p50_ms,
            r.latency_p99_ms,
            slo,
        ));
    }
    out
}

/// Serialize serving rows as the `BENCH_serving.json` document.
pub fn serving_json(rows: &[ServingRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("backend", s(&r.backend)),
                    ("max_batch", num(r.max_batch as f64)),
                    ("workers", num(r.workers as f64)),
                    ("requests", num(r.requests as f64)),
                    ("errors", num(r.errors as f64)),
                    ("mean_batch", num(r.mean_batch)),
                    ("throughput_rps", num(r.throughput_rps)),
                    (
                        "latency_ms",
                        obj(vec![
                            ("mean", num(r.latency_mean_ms)),
                            ("p50", num(r.latency_p50_ms)),
                            ("p99", num(r.latency_p99_ms)),
                        ]),
                    ),
                    (
                        "slo",
                        match r.slo_ms {
                            Some(target) => obj(vec![
                                ("target_ms", num(target)),
                                ("violations", num(r.slo_violations as f64)),
                            ]),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect(),
    )
}

/// One row of the per-layer deployment-plan report: a layer's per-slice
/// ADC resolutions plus its savings vs the 8-bit baseline — exactly
/// [`energy::layer_costs`]'s output, consumed directly (like
/// [`adc_table`] consumes [`AdcSavingRow`]). `adc_bits` is LSB-first (see
/// the bit-order docs in [`crate::reram`]); the rendered table lists the
/// paper's MSB-first `XB_k` columns.
///
/// [`energy::layer_costs`]: crate::reram::energy::layer_costs
pub use crate::reram::energy::LayerCost as PlanRow;

/// Render the per-layer deployment plan (markdown).
pub fn plan_table(title: &str, rows: &[PlanRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("### {title}\n\n"));
    out.push_str(
        "| Layer | XB_3 | XB_2 | XB_1 | XB_0 | Crossbars | Energy Saving | Speedup | Area Saving |\n\
         |-------|------|------|------|------|-----------|---------------|---------|-------------|\n",
    );
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {:.1}x | {:.2}x | {:.1}x |\n",
            r.layer,
            r.adc_bits[3],
            r.adc_bits[2],
            r.adc_bits[1],
            r.adc_bits[0],
            r.crossbars,
            r.energy_saving,
            r.time_saving,
            r.area_saving,
        ));
    }
    out
}

/// One-line rendering of a search's instrumentation counters, for CLI
/// output and bench logs.
pub fn search_stats_line(stats: &SearchStats) -> String {
    format!(
        "{} evaluations, {} layer-forwards, {} cache hits, {} early-aborted, {} noise-rejected",
        stats.evaluations,
        stats.layer_forwards,
        stats.cache_hits,
        stats.aborted_evals,
        stats.noise_rejections
    )
}

/// Serialize a planner run as the `BENCH_planner.json` / `plan.json`
/// document. `timing` carries the per-layer latency/replica rows and the
/// pipeline throughput roll-up of the same plan (see
/// [`crate::reram::timing`]); `stats` lands both at the legacy top-level
/// `evaluations` key and in full under `search`.
pub fn planner_json(
    rows: &[PlanRow],
    baseline_accuracy: f64,
    accuracy: f64,
    accuracy_budget: f64,
    savings: (f64, f64, f64),
    stats: &SearchStats,
    timing: &PipelineTiming,
) -> Json {
    let layers = rows
        .iter()
        .map(|r| {
            obj(vec![
                ("layer", s(&r.layer)),
                (
                    "adc_bits_lsb_first",
                    Json::Arr(r.adc_bits.iter().map(|&b| num(b as f64)).collect()),
                ),
                ("replicas", num(r.replicas as f64)),
                ("crossbars", num(r.crossbars as f64)),
                ("energy_saving", num(r.energy_saving)),
                ("time_saving", num(r.time_saving)),
                ("area_saving", num(r.area_saving)),
            ])
        })
        .collect();
    obj(vec![
        ("baseline_accuracy", num(baseline_accuracy)),
        ("accuracy", num(accuracy)),
        ("accuracy_budget", num(accuracy_budget)),
        ("evaluations", num(stats.evaluations as f64)),
        (
            "search",
            obj(vec![
                ("evaluations", num(stats.evaluations as f64)),
                ("layer_forwards", num(stats.layer_forwards as f64)),
                ("cache_hits", num(stats.cache_hits as f64)),
                ("aborted_evals", num(stats.aborted_evals as f64)),
                ("noise_rejections", num(stats.noise_rejections as f64)),
            ]),
        ),
        (
            "savings",
            obj(vec![
                ("energy", num(savings.0)),
                ("time", num(savings.1)),
                ("area", num(savings.2)),
            ]),
        ),
        ("layers", Json::Arr(layers)),
        ("timing", timing_json(timing)),
    ])
}

/// One row of the crossbar storage report: a layer's tile-format census —
/// exactly [`mapper::storage_rows`]'s output, consumed directly (like
/// [`plan_table`] consumes [`PlanRow`]).
///
/// [`mapper::storage_rows`]: crate::reram::mapper::MappedModel::storage_rows
pub use crate::reram::mapper::StorageRow;

/// Render the per-layer crossbar storage census (markdown): tiles dense
/// vs bit-plane vs compressed, the fully-zero tiles the simulator skips,
/// mapped-cell density, active wordline/column occupancy of the
/// programmed tiles, and bytes under the chosen layouts vs an all-dense
/// layout.
pub fn storage_table(title: &str, rows: &[StorageRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("### {title}\n\n"));
    out.push_str(
        "| Layer | Dense | BitPlanes | Compressed | Skipped | Density | Act. WL | Act. cols | Bytes | Dense bytes | Saving |\n\
         |-------|-------|-----------|------------|---------|---------|---------|-----------|-------|-------------|--------|\n",
    );
    let mut total = crate::reram::mapper::StorageStats::default();
    for r in rows {
        let s = &r.stats;
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {:.2}% | {:.1}% | {:.1}% | {} | {} | {:.2}x |\n",
            r.layer,
            s.dense_tiles,
            s.bitplane_tiles,
            s.compressed_tiles,
            s.skipped_tiles,
            s.density() * 100.0,
            s.wordline_occupancy() * 100.0,
            s.column_occupancy() * 100.0,
            s.bytes,
            s.dense_bytes,
            s.byte_saving(),
        ));
        total.merge(s);
    }
    if rows.len() > 1 {
        out.push_str(&format!(
            "| total | {} | {} | {} | {} | {:.2}% | {:.1}% | {:.1}% | {} | {} | {:.2}x |\n",
            total.dense_tiles,
            total.bitplane_tiles,
            total.compressed_tiles,
            total.skipped_tiles,
            total.density() * 100.0,
            total.wordline_occupancy() * 100.0,
            total.column_occupancy() * 100.0,
            total.bytes,
            total.dense_bytes,
            total.byte_saving(),
        ));
    }
    out
}

/// Serialize storage rows — the deploy CLI's `<out>/storage.json`
/// document.
pub fn storage_json(rows: &[StorageRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let st = &r.stats;
                obj(vec![
                    ("layer", s(&r.layer)),
                    ("dense_tiles", num(st.dense_tiles as f64)),
                    ("bitplane_tiles", num(st.bitplane_tiles as f64)),
                    ("compressed_tiles", num(st.compressed_tiles as f64)),
                    ("skipped_tiles", num(st.skipped_tiles as f64)),
                    ("programmed_cells", num(st.programmed_cells as f64)),
                    ("cells", num(st.cells as f64)),
                    ("bytes", num(st.bytes as f64)),
                    ("dense_bytes", num(st.dense_bytes as f64)),
                    ("active_wordlines", num(st.active_wordlines as f64)),
                    ("wordline_slots", num(st.wordline_slots as f64)),
                    ("active_columns", num(st.active_columns as f64)),
                    ("column_slots", num(st.column_slots as f64)),
                ])
            })
            .collect(),
    )
}

/// One row of the reorder report: a layer's storage census under the
/// reordered mapping next to the natural-order baseline — exactly
/// [`reorder::reorder_rows`]'s output, consumed directly (like
/// [`storage_table`] consumes [`StorageRow`]).
///
/// [`reorder::reorder_rows`]: crate::reram::reorder::reorder_rows
pub use crate::reram::reorder::ReorderRow;

/// Render the per-layer wordline/column reorder effect (markdown):
/// active wordlines, active columns and skipped tiles, reordered vs the
/// natural-order baseline.
pub fn reorder_table(title: &str, rows: &[ReorderRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("### {title}\n\n"));
    out.push_str(
        "| Layer | Act. WL | was | Saving | Act. cols | was | Saving | Skipped | was |\n\
         |-------|---------|-----|--------|-----------|-----|--------|---------|-----|\n",
    );
    let mut base = crate::reram::mapper::StorageStats::default();
    let mut reord = crate::reram::mapper::StorageStats::default();
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {} | {:.2}x | {} | {} | {:.2}x | {} | {} |\n",
            r.layer,
            r.reordered.active_wordlines,
            r.baseline.active_wordlines,
            r.wordline_saving(),
            r.reordered.active_columns,
            r.baseline.active_columns,
            r.column_saving(),
            r.reordered.skipped_tiles,
            r.baseline.skipped_tiles,
        ));
        base.merge(&r.baseline);
        reord.merge(&r.reordered);
    }
    if rows.len() > 1 {
        let total = ReorderRow {
            layer: "total".into(),
            baseline: base,
            reordered: reord,
        };
        out.push_str(&format!(
            "| total | {} | {} | {:.2}x | {} | {} | {:.2}x | {} | {} |\n",
            total.reordered.active_wordlines,
            total.baseline.active_wordlines,
            total.wordline_saving(),
            total.reordered.active_columns,
            total.baseline.active_columns,
            total.column_saving(),
            total.reordered.skipped_tiles,
            total.baseline.skipped_tiles,
        ));
    }
    out
}

/// Serialize reorder rows — the deploy CLI's `<out>/reorder.json`
/// document.
pub fn reorder_json(rows: &[ReorderRow]) -> Json {
    let side = |st: &crate::reram::mapper::StorageStats| {
        obj(vec![
            ("active_wordlines", num(st.active_wordlines as f64)),
            ("wordline_slots", num(st.wordline_slots as f64)),
            ("active_columns", num(st.active_columns as f64)),
            ("column_slots", num(st.column_slots as f64)),
            ("programmed_tiles", num(st.programmed_tiles() as f64)),
            ("skipped_tiles", num(st.skipped_tiles as f64)),
            ("bytes", num(st.bytes as f64)),
        ])
    };
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("layer", s(&r.layer)),
                    ("baseline", side(&r.baseline)),
                    ("reordered", side(&r.reordered)),
                    ("wordline_saving", num(r.wordline_saving())),
                    ("column_saving", num(r.column_saving())),
                    ("tile_saving", num(r.tile_saving())),
                ])
            })
            .collect(),
    )
}

/// The whole-pipeline timing roll-up under a plan — exactly
/// [`timing::plan_timing`]'s output, consumed directly (like
/// [`plan_table`] consumes [`PlanRow`]). One [`TimingRow`] per layer.
///
/// [`timing::plan_timing`]: crate::reram::timing::plan_timing
pub use crate::reram::timing::{LayerTiming as TimingRow, PipelineTiming};

/// Render the per-layer pipeline timing (markdown): each layer's
/// per-example latency in cycles, replica count, replica-divided
/// effective stage latency and total conversion cycles, with the
/// bottleneck stage marked, followed by the steady-state throughput
/// roll-up. A cycle is one ADC bit-resolution step (see the timing
/// convention in [`crate::reram`]).
pub fn timing_table(title: &str, timing: &PipelineTiming) -> String {
    let bottleneck = timing.bottleneck();
    let mut out = String::new();
    out.push_str(&format!("### {title}\n\n"));
    out.push_str(
        "| Layer | Replicas | Latency (cyc) | Effective (cyc) | Conversion (cyc) | Bottleneck |\n\
         |-------|----------|---------------|-----------------|------------------|------------|\n",
    );
    for (i, r) in timing.layers.iter().enumerate() {
        out.push_str(&format!(
            "| {} | {} | {} | {:.1} | {} | {} |\n",
            r.layer,
            r.replicas,
            r.latency_cycles,
            r.effective_cycles(),
            r.conversion_cycles,
            if bottleneck == Some(i) { "<-" } else { "" },
        ));
    }
    out.push_str(&format!(
        "\npipeline: {:.1} cyc/example steady-state ({:.2} examples/kcycle), \
         fill latency {} cyc\n",
        timing.bottleneck_cycles(),
        timing.throughput_per_kcycle(),
        timing.pipeline_fill_cycles(),
    ));
    out
}

/// Serialize a pipeline timing roll-up — the `timing` object of
/// `plan.json` and `BENCH_pipeline.json`.
pub fn timing_json(timing: &PipelineTiming) -> Json {
    let layers = timing
        .layers
        .iter()
        .map(|r| {
            obj(vec![
                ("layer", s(&r.layer)),
                ("replicas", num(r.replicas as f64)),
                ("latency_cycles", num(r.latency_cycles as f64)),
                ("effective_cycles", num(r.effective_cycles())),
                ("conversion_cycles", num(r.conversion_cycles as f64)),
            ])
        })
        .collect();
    obj(vec![
        (
            "bottleneck_layer",
            match timing.bottleneck() {
                Some(i) => s(&timing.layers[i].layer),
                None => Json::Null,
            },
        ),
        ("bottleneck_cycles", num(timing.bottleneck_cycles())),
        ("throughput_per_kcycle", num(timing.throughput_per_kcycle())),
        (
            "pipeline_fill_cycles",
            num(timing.pipeline_fill_cycles() as f64),
        ),
        ("layers", Json::Arr(layers)),
    ])
}

/// One row of the Monte-Carlo noise study: accuracy statistics over N
/// seeded device realizations of one non-ideality operating point
/// ([`crate::harness::noise_report`] builds it, a sigma sweep of them is
/// the Fig-2-style accuracy-vs-variation series of `BENCH_noise.json`).
#[derive(Debug, Clone)]
pub struct NoiseRow {
    /// the operating point every trial shares (trial `i` derives its own
    /// seed via [`DeviceConfig::trial`])
    pub config: DeviceConfig,
    /// accuracy with no device attached — the bit-exact ideal path
    pub ideal_accuracy: f64,
    /// per-trial accuracy, one seeded realization each
    pub trial_accuracies: Vec<f64>,
    pub mean_accuracy: f64,
    pub worst_accuracy: f64,
    /// per-layer per-slice-group mean squared conductance deviation
    /// (LSB², trial 0's realization): which slice groups the non-ideality
    /// actually lands on — sparse groups hold fewer programmed cells, so
    /// less of the spread reaches their bitlines
    pub layer_variance: Vec<(String, [f64; N_SLICES])>,
}

impl NoiseRow {
    /// Accuracy lost to the non-ideality: ideal minus Monte-Carlo mean.
    pub fn mean_drop(&self) -> f64 {
        self.ideal_accuracy - self.mean_accuracy
    }
}

/// Render the accuracy-vs-variation study (markdown): one row per
/// operating point, mean/worst over that point's seeded trials.
pub fn noise_table(title: &str, rows: &[NoiseRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("### {title}\n\n"));
    out.push_str(
        "| Sigma | Read sigma | Fault rate | Trials | Ideal | Mean | Worst | Mean drop (pt) |\n\
         |-------|------------|------------|--------|-------|------|-------|----------------|\n",
    );
    for r in rows {
        out.push_str(&format!(
            "| {:.2} | {:.2} | {:.3} | {} | {:.2}% | {:.2}% | {:.2}% | {:.2} |\n",
            r.config.sigma,
            r.config.read_sigma,
            r.config.fault_rate,
            r.trial_accuracies.len(),
            r.ideal_accuracy * 100.0,
            r.mean_accuracy * 100.0,
            r.worst_accuracy * 100.0,
            r.mean_drop() * 100.0,
        ));
    }
    out
}

/// Serialize one noise study series — the per-series body of
/// `BENCH_noise.json` (the bench nests one per fixture).
pub fn noise_json(rows: &[NoiseRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let layers = r
                    .layer_variance
                    .iter()
                    .map(|(name, v)| {
                        obj(vec![
                            ("layer", s(name)),
                            (
                                "variance_lsb2_lsb_first",
                                Json::Arr(v.iter().map(|&x| num(x)).collect()),
                            ),
                        ])
                    })
                    .collect();
                obj(vec![
                    ("sigma", num(r.config.sigma as f64)),
                    ("read_sigma", num(r.config.read_sigma as f64)),
                    ("fault_rate", num(r.config.fault_rate as f64)),
                    ("seed", num(r.config.seed as f64)),
                    ("ideal_accuracy", num(r.ideal_accuracy)),
                    ("mean_accuracy", num(r.mean_accuracy)),
                    ("worst_accuracy", num(r.worst_accuracy)),
                    ("mean_drop", num(r.mean_drop())),
                    (
                        "trial_accuracies",
                        Json::Arr(r.trial_accuracies.iter().map(|&a| num(a)).collect()),
                    ),
                    ("layer_variance", Json::Arr(layers)),
                ])
            })
            .collect(),
    )
}

pub use crate::reram::audit::{AuditReport, AuditSummary};

/// Render an audit report (markdown): the scan roll-up plus one row per
/// diagnostic — stable code, severity, layer, tile and message (the
/// `deploy --audit` / `audit` subcommand human view).
pub fn audit_table(title: &str, report: &AuditReport) -> String {
    let mut out = String::new();
    out.push_str(&format!("### {title}\n\n"));
    out.push_str(&format!(
        "{} tiles scanned: {} errors, {} warnings\n\n",
        report.summary.tiles, report.summary.errors, report.summary.warnings
    ));
    if report.diagnostics.is_empty() {
        out.push_str("no findings — every audited invariant holds\n");
        return out;
    }
    out.push_str(
        "| Code | Severity | Layer | Tile | Message |\n\
         |------|----------|-------|------|---------|\n",
    );
    for d in &report.diagnostics {
        out.push_str(&format!(
            "| {} {} | {} | {} | {} | {} |\n",
            d.code.code(),
            d.code.name(),
            d.severity,
            d.layer,
            d.tile,
            d.message
        ));
    }
    out
}

/// Serialize just the audit roll-up counts — what `deploy_report` and the
/// bench artifacts embed to record they ran on a verified mapping.
pub fn audit_summary_json(summary: &AuditSummary) -> Json {
    obj(vec![
        ("tiles_scanned", num(summary.tiles as f64)),
        ("errors", num(summary.errors as f64)),
        ("warnings", num(summary.warnings as f64)),
    ])
}

/// Serialize a full audit report — the `<out>/audit.json` artifact
/// (deterministic: object keys sort, diagnostics keep scan order).
pub fn audit_json(report: &AuditReport) -> Json {
    let diags = report
        .diagnostics
        .iter()
        .map(|d| {
            obj(vec![
                ("code", s(d.code.code())),
                ("name", s(d.code.name())),
                ("severity", s(&d.severity.to_string())),
                ("layer", s(&d.layer)),
                ("tile", s(&d.tile)),
                ("message", s(&d.message)),
            ])
        })
        .collect();
    obj(vec![
        ("summary", audit_summary_json(&report.summary)),
        ("diagnostics", Json::Arr(diags)),
    ])
}

/// Per-slice resolution summary (feeds Table 3's "Resolution" column from
/// the measured mapping instead of asserting it).
pub fn resolution_summary(bits_lsb_first: [u32; N_SLICES]) -> String {
    let mut out = String::from("| Group | Required ADC bits |\n|-------|-------------------|\n");
    for k in (0..N_SLICES).rev() {
        out.push_str(&format!("| XB_{k} | {} |\n", bits_lsb_first[k]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::energy::saving_row;
    use crate::sparsity::SliceStats;

    fn stats(nonzero: [usize; 4], numel: usize) -> SliceStats {
        SliceStats { nonzero, numel }
    }

    #[test]
    fn sparsity_table_formats_rows() {
        let rows = vec![MethodRow {
            method: "Bl1".into(),
            accuracy: 0.9767,
            // LSB-first counts out of 1000: b0 96, b1 43, b2 40, b3 8
            stats: stats([96, 43, 40, 8], 1000),
        }];
        let t = sparsity_table("MNIST", &rows);
        assert!(t.contains("97.67%"));
        assert!(t.contains("| 0.80% | 4.00% | 4.30% | 9.60% |"), "{t}");
        assert!(t.contains("±"));
    }

    #[test]
    fn adc_table_matches_paper_numbers() {
        let t = adc_table(&[saving_row(3, 1), saving_row(2, 3)]);
        assert!(t.contains("XB_3"));
        assert!(t.contains("28.4x"));
        assert!(t.contains("2.67x"));
        assert!(t.contains("| 2x |"));
    }

    #[test]
    fn fig2_csv_has_method_column() {
        let traces = vec![(
            "bl1".to_string(),
            vec![crate::sparsity::TracePoint {
                step: 10,
                ratios: [0.01, 0.02, 0.03, 0.04],
            }],
        )];
        let csv = fig2_csv(&traces);
        assert!(csv.starts_with("method,step,"));
        assert!(csv.contains("bl1,10,0.010000"));
    }

    fn serving_row() -> ServingRow {
        ServingRow {
            backend: "crossbar@lossless".into(),
            max_batch: 32,
            workers: 4,
            requests: 1000,
            errors: 7,
            mean_batch: 12.5,
            throughput_rps: 842.0,
            latency_mean_ms: 3.2,
            latency_p50_ms: 2.9,
            latency_p99_ms: 9.4,
            slo_ms: None,
            slo_violations: 0,
        }
    }

    #[test]
    fn serving_table_formats_rows() {
        let t = serving_table(&[serving_row()]);
        assert!(t.contains("crossbar@lossless"));
        assert!(t.contains("| 32 |"));
        assert!(t.contains("842"));
        assert!(t.contains("9.400"));
        assert!(t.contains("| - |"), "no SLO policy renders as a dash: {t}");
        let mut slo_row = serving_row();
        slo_row.slo_ms = Some(10.0);
        slo_row.slo_violations = 12;
        let t = serving_table(&[slo_row]);
        assert!(t.contains("| 12/1000 @ 10 ms |"), "{t}");
    }

    #[test]
    fn serving_json_roundtrips() {
        let j = serving_json(&[serving_row()]);
        let text = j.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        let row = &back.as_arr().unwrap()[0];
        assert_eq!(row.get("backend").unwrap().as_str(), Some("crossbar@lossless"));
        assert_eq!(row.get("requests").unwrap().as_usize(), Some(1000));
        assert_eq!(row.get("errors").unwrap().as_usize(), Some(7));
        let lat = row.get("latency_ms").unwrap();
        assert_eq!(lat.get("p99").unwrap().as_f64(), Some(9.4));
        assert!(matches!(row.get("slo"), Some(Json::Null)), "no policy -> null slo");
        let mut slo_row = serving_row();
        slo_row.slo_ms = Some(10.0);
        slo_row.slo_violations = 12;
        let back = crate::util::json::parse(&serving_json(&[slo_row]).to_string()).unwrap();
        let slo = back.as_arr().unwrap()[0].get("slo").unwrap();
        assert_eq!(slo.get("target_ms").unwrap().as_f64(), Some(10.0));
        assert_eq!(slo.get("violations").unwrap().as_usize(), Some(12));
    }

    fn plan_row() -> PlanRow {
        PlanRow {
            layer: "fc1/w".into(),
            adc_bits: [3, 3, 2, 1], // LSB-first
            replicas: 1,
            crossbars: 42,
            energy: 120.0,
            time: 40.0,
            area: 21.0,
            energy_saving: 16.3,
            time_saving: 2.91,
            area_saving: 2.0,
        }
    }

    #[test]
    fn plan_table_renders_msb_first() {
        let t = plan_table("plan", &[plan_row()]);
        // XB_3 column (MSB) shows the LSB-first array's last entry
        assert!(t.contains("| fc1/w | 1 | 2 | 3 | 3 | 42 | 16.3x | 2.91x | 2.0x |"), "{t}");
        assert!(t.contains("XB_3"));
    }

    fn timing_fixture() -> PipelineTiming {
        PipelineTiming {
            layers: vec![
                TimingRow {
                    layer: "fc1/w".into(),
                    replicas: 1,
                    latency_cycles: 768,
                    conversion_cycles: 768,
                },
                TimingRow {
                    layer: "fc2/w".into(),
                    replicas: 2,
                    latency_cycles: 3072,
                    conversion_cycles: 9216,
                },
            ],
        }
    }

    #[test]
    fn planner_json_roundtrips() {
        let stats = SearchStats {
            evaluations: 37,
            layer_forwards: 1520,
            cache_hits: 4880,
            aborted_evals: 9,
            noise_rejections: 3,
        };
        let j = planner_json(
            &[plan_row()],
            0.9767,
            0.9741,
            0.005,
            (16.3, 2.91, 2.0),
            &stats,
            &timing_fixture(),
        );
        let back = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("baseline_accuracy").unwrap().as_f64(), Some(0.9767));
        // the legacy top-level key mirrors the full search object
        assert_eq!(back.get("evaluations").unwrap().as_usize(), Some(37));
        let search = back.get("search").unwrap();
        assert_eq!(search.get("evaluations").unwrap().as_usize(), Some(37));
        assert_eq!(search.get("layer_forwards").unwrap().as_usize(), Some(1520));
        assert_eq!(search.get("cache_hits").unwrap().as_usize(), Some(4880));
        assert_eq!(search.get("aborted_evals").unwrap().as_usize(), Some(9));
        assert_eq!(search.get("noise_rejections").unwrap().as_usize(), Some(3));
        let line = search_stats_line(&stats);
        assert!(line.contains("37 evaluations"), "{line}");
        assert!(line.contains("4880 cache hits"), "{line}");
        let layers = back.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers[0].get("layer").unwrap().as_str(), Some("fc1/w"));
        assert_eq!(layers[0].get("replicas").unwrap().as_usize(), Some(1));
        let bits = layers[0].get("adc_bits_lsb_first").unwrap().as_arr().unwrap();
        assert_eq!(bits.len(), 4);
        assert_eq!(bits[3].as_usize(), Some(1));
        let savings = back.get("savings").unwrap();
        assert_eq!(savings.get("energy").unwrap().as_f64(), Some(16.3));
        // the timing rows ride along in the same document
        let timing = back.get("timing").unwrap();
        assert_eq!(timing.get("bottleneck_layer").unwrap().as_str(), Some("fc2/w"));
        let trows = timing.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(trows[1].get("replicas").unwrap().as_usize(), Some(2));
        assert_eq!(trows[1].get("latency_cycles").unwrap().as_usize(), Some(3072));
    }

    #[test]
    fn timing_table_marks_the_bottleneck() {
        let t = timing_table("pipeline", &timing_fixture());
        // fc2 at 3072/2 = 1536 effective is the bottleneck stage
        assert!(t.contains("| fc2/w | 2 | 3072 | 1536.0 | 9216 | <- |"), "{t}");
        assert!(t.contains("| fc1/w | 1 | 768 | 768.0 | 768 |  |"), "{t}");
        assert!(t.contains("1536.0 cyc/example"), "{t}");
        assert!(t.contains("fill latency 3840 cyc"), "{t}");
    }

    #[test]
    fn timing_json_roundtrips() {
        let j = timing_json(&timing_fixture());
        let back = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bottleneck_layer").unwrap().as_str(), Some("fc2/w"));
        assert_eq!(back.get("bottleneck_cycles").unwrap().as_f64(), Some(1536.0));
        assert_eq!(
            back.get("pipeline_fill_cycles").unwrap().as_usize(),
            Some(3840)
        );
        let layers = back.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers[0].get("layer").unwrap().as_str(), Some("fc1/w"));
        assert_eq!(layers[0].get("effective_cycles").unwrap().as_f64(), Some(768.0));
    }

    fn storage_row(layer: &str, dense: usize, bp: usize, comp: usize) -> StorageRow {
        StorageRow {
            layer: layer.into(),
            stats: crate::reram::mapper::StorageStats {
                dense_tiles: dense,
                bitplane_tiles: bp,
                compressed_tiles: comp,
                skipped_tiles: 1,
                programmed_cells: 500,
                cells: 10_000,
                bytes: 2_600,
                dense_bytes: 10_000,
                active_wordlines: 40,
                wordline_slots: 100,
                active_columns: 20,
                column_slots: 50,
            },
        }
    }

    #[test]
    fn storage_table_formats_rows_and_total() {
        let t = storage_table(
            "storage",
            &[storage_row("fc1/w", 2, 4, 5), storage_row("fc2/w", 0, 1, 3)],
        );
        assert!(
            t.contains("| fc1/w | 2 | 4 | 5 | 1 | 5.00% | 40.0% | 40.0% | 2600 | 10000 | 3.85x |"),
            "{t}"
        );
        assert!(
            t.contains("| total | 2 | 5 | 8 | 2 | 5.00% | 40.0% | 40.0% | 5200 | 20000 | 3.85x |"),
            "{t}"
        );
        // single-row tables skip the redundant total line
        let one = storage_table("storage", &[storage_row("fc1/w", 2, 4, 5)]);
        assert!(!one.contains("| total |"), "{one}");
    }

    #[test]
    fn storage_json_roundtrips() {
        let j = storage_json(&[storage_row("fc1/w", 2, 4, 5)]);
        let back = crate::util::json::parse(&j.to_string()).unwrap();
        let row = &back.as_arr().unwrap()[0];
        assert_eq!(row.get("layer").unwrap().as_str(), Some("fc1/w"));
        assert_eq!(row.get("bitplane_tiles").unwrap().as_usize(), Some(4));
        assert_eq!(row.get("compressed_tiles").unwrap().as_usize(), Some(5));
        assert_eq!(row.get("bytes").unwrap().as_usize(), Some(2600));
        assert_eq!(row.get("dense_bytes").unwrap().as_usize(), Some(10000));
        assert_eq!(row.get("active_wordlines").unwrap().as_usize(), Some(40));
        assert_eq!(row.get("active_columns").unwrap().as_usize(), Some(20));
    }

    fn reorder_row() -> ReorderRow {
        let mut baseline = storage_row("fc1/w", 2, 4, 5).stats;
        baseline.active_wordlines = 120;
        baseline.active_columns = 60;
        baseline.skipped_tiles = 0;
        let mut reordered = baseline;
        reordered.active_wordlines = 40;
        reordered.active_columns = 20;
        reordered.skipped_tiles = 4;
        ReorderRow {
            layer: "fc1/w".into(),
            baseline,
            reordered,
        }
    }

    #[test]
    fn reorder_table_shows_savings() {
        let t = reorder_table("reorder", &[reorder_row()]);
        assert!(
            t.contains("| fc1/w | 40 | 120 | 3.00x | 20 | 60 | 3.00x | 4 | 0 |"),
            "{t}"
        );
        assert!(!t.contains("| total |"), "{t}");
        // two rows roll up into a total line
        let two = reorder_table("reorder", &[reorder_row(), reorder_row()]);
        assert!(
            two.contains("| total | 80 | 240 | 3.00x | 40 | 120 | 3.00x | 8 | 0 |"),
            "{two}"
        );
    }

    #[test]
    fn reorder_json_roundtrips() {
        let j = reorder_json(&[reorder_row()]);
        let back = crate::util::json::parse(&j.to_string()).unwrap();
        let row = &back.as_arr().unwrap()[0];
        assert_eq!(row.get("layer").unwrap().as_str(), Some("fc1/w"));
        assert_eq!(row.get("wordline_saving").unwrap().as_f64(), Some(3.0));
        let b = row.get("baseline").unwrap();
        let r = row.get("reordered").unwrap();
        assert_eq!(b.get("active_wordlines").unwrap().as_usize(), Some(120));
        assert_eq!(r.get("active_wordlines").unwrap().as_usize(), Some(40));
        assert_eq!(r.get("skipped_tiles").unwrap().as_usize(), Some(4));
        // programmed tiles sum all three storage formats
        assert_eq!(b.get("programmed_tiles").unwrap().as_usize(), Some(11));
    }

    #[test]
    fn resolution_summary_msb_first() {
        let s = resolution_summary([3, 3, 3, 1]);
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[2].contains("XB_3 | 1"));
        assert!(lines[5].contains("XB_0 | 3"));
    }

    fn audit_fixture() -> AuditReport {
        use crate::reram::audit::{AuditCode, Diagnostic, Severity};
        AuditReport {
            summary: AuditSummary {
                tiles: 48,
                errors: 1,
                warnings: 1,
            },
            diagnostics: vec![
                Diagnostic {
                    code: AuditCode::CensusMismatch,
                    severity: Severity::Error,
                    layer: "fc1/w".into(),
                    tile: "XB_2/pos[0,1]".into(),
                    message: "cached census 7 != store recount 6".into(),
                },
                Diagnostic {
                    code: AuditCode::FormatBandDrift,
                    severity: Severity::Warning,
                    layer: "fc2/w".into(),
                    tile: "XB_0/neg[0,0]".into(),
                    message: "stored Dense where the density band (5.0%) chooses Compressed"
                        .into(),
                },
            ],
        }
    }

    #[test]
    fn audit_table_lists_findings_with_stable_codes() {
        let t = audit_table("Deployment audit", &audit_fixture());
        assert!(t.contains("48 tiles scanned: 1 errors, 1 warnings"));
        assert!(t.contains("| A002 CensusMismatch | error | fc1/w | XB_2/pos[0,1] |"));
        assert!(t.contains("| A009 FormatBandDrift | warning | fc2/w |"));
        // a clean report renders the explicit all-clear line
        let clean = AuditReport {
            summary: AuditSummary {
                tiles: 12,
                errors: 0,
                warnings: 0,
            },
            diagnostics: vec![],
        };
        let t = audit_table("Deployment audit", &clean);
        assert!(t.contains("no findings"));
        assert!(!t.contains("| Code |"));
    }

    #[test]
    fn audit_json_roundtrips() {
        let j = audit_json(&audit_fixture());
        let back = crate::util::json::parse(&j.to_string()).unwrap();
        let summary = back.get("summary").unwrap();
        assert_eq!(summary.get("tiles_scanned").unwrap().as_usize(), Some(48));
        assert_eq!(summary.get("errors").unwrap().as_usize(), Some(1));
        assert_eq!(summary.get("warnings").unwrap().as_usize(), Some(1));
        let diags = back.get("diagnostics").unwrap().as_arr().unwrap();
        assert_eq!(diags.len(), 2);
        assert_eq!(diags[0].get("code").unwrap().as_str(), Some("A002"));
        assert_eq!(diags[0].get("severity").unwrap().as_str(), Some("error"));
        assert_eq!(diags[1].get("code").unwrap().as_str(), Some("A009"));
        assert_eq!(
            diags[1].get("name").unwrap().as_str(),
            Some("FormatBandDrift")
        );
    }
}
